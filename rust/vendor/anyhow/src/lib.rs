//! Vendored, dependency-free shim exposing the subset of the `anyhow` API
//! this workspace uses: `Error`, `Result`, the `Context` extension trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics intentionally simplified: an `Error` is a message string, and
//! `context` prepends to it (so both `{}` and `{:#}` render the full chain).
//! Like the real crate, `Error` deliberately does not implement
//! `std::error::Error` — that keeps the blanket `From<E: std::error::Error>`
//! conversion (used by `?`) coherent.

use std::fmt;

/// A message-carrying error type, convertible from any std error via `?`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }

    /// Prepend a context layer, `anyhow`-style (`context: cause`).
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{}: {}", ctx, self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`: attach context to the error arm of a `Result`, or
/// convert a `None` into an error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", ctx, e)))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", f(), e)))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ctx(s: &str) -> Result<u32> {
        let n: u32 = s.parse().context("bad number")?;
        Ok(n)
    }

    #[test]
    fn context_prepends() {
        let e = parse_ctx("nope").unwrap_err();
        assert!(e.to_string().starts_with("bad number: "), "{}", e);
        assert_eq!(parse_ctx("7").unwrap(), 7);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io_err() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
            Ok(s)
        }
        assert!(io_err().is_err());
    }

    #[test]
    fn macros_build_messages() {
        let plain = anyhow!("plain");
        assert_eq!(plain.to_string(), "plain");
        let fmt = anyhow!("x = {}", 42);
        assert_eq!(fmt.to_string(), "x = 42");
        let from_string = anyhow!(String::from("owned"));
        assert_eq!(from_string.to_string(), "owned");
    }

    #[test]
    fn ensure_and_bail() {
        fn check(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {}", n);
            if n == 0 {
                bail!("zero not allowed");
            }
            Ok(n)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(11).unwrap_err().to_string().contains("too big"));
        assert!(check(0).unwrap_err().to_string().contains("zero"));
    }
}
