//! Minimal statistical bench harness (criterion is not in the offline
//! vendor set). Warms up, runs timed iterations, prints mean/median/p95.
//!
//! Smoke mode (`--smoke` flag or SHARED_PIM_SMOKE=1) shrinks iteration
//! counts and workload scales so every bench finishes in seconds — used by
//! the CI bench-smoke step to keep the targets compiling *and running*.
//!
//! Set BENCH_JSON=<file> to additionally capture named metrics as JSON in
//! the same `{name, value, direction}` shape the `repro gate` metric-list
//! arms consume (see [`MetricSink`]).

use shared_pim::util::json::{obj, Json};
use shared_pim::util::stats::summarize;
use std::time::Instant;

pub struct Bench {
    pub name: String,
    samples: Vec<f64>, // seconds
}

impl Bench {
    pub fn run(name: impl Into<String>, iters: usize, mut f: impl FnMut()) -> Bench {
        // warmup
        f();
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        Bench { name: name.into(), samples }
    }

    pub fn report(&self) -> f64 {
        let s = summarize(&self.samples);
        println!(
            "{:<44} mean {:>10} median {:>10} p95 {:>10} (n={})",
            self.name,
            fmt_s(s.mean),
            fmt_s(s.median),
            fmt_s(s.p95),
            s.n
        );
        s.mean
    }

    /// Report with a derived throughput line.
    pub fn report_throughput(&self, items: f64, unit: &str) -> f64 {
        let mean = self.report();
        println!("{:<44}   -> {:.2} {}/s", "", items / mean, unit);
        mean
    }
}

/// Optional machine-readable metric capture: when the BENCH_JSON env var
/// names a file, [`MetricSink::write`] lands the pushed metrics there as
/// `{schema, bench, metrics: [{name, value, direction}, ...]}` — the same
/// metric shape `repro gate` checks, so downstream tooling can diff bench
/// runs without scraping stdout. Without BENCH_JSON the sink is inert.
#[allow(dead_code)] // not every bench target exports metrics
pub struct MetricSink {
    out: Option<std::path::PathBuf>,
    metrics: Vec<Json>,
}

#[allow(dead_code)]
impl MetricSink {
    /// Schema tag of the bench-metrics file.
    pub const SCHEMA: &'static str = "shared-pim/bench-metrics/v1";

    pub fn from_env() -> MetricSink {
        MetricSink {
            out: std::env::var_os("BENCH_JSON").map(Into::into),
            metrics: Vec::new(),
        }
    }

    /// Record one named metric; `direction` is `"higher"` (throughputs) or
    /// `"lower"` (latencies).
    pub fn push(&mut self, name: &str, value: f64, direction: &str) {
        self.metrics.push(obj(vec![
            ("name", Json::Str(name.to_string())),
            ("value", Json::Num(value)),
            ("direction", Json::Str(direction.to_string())),
        ]));
    }

    /// Write the captured metrics (no-op without BENCH_JSON). `bench` names
    /// the producing bench target inside the file.
    pub fn write(&self, bench: &str) {
        let Some(out) = &self.out else { return };
        let j = obj(vec![
            ("schema", Json::Str(Self::SCHEMA.to_string())),
            ("bench", Json::Str(bench.to_string())),
            ("metrics", Json::Arr(self.metrics.clone())),
        ]);
        match std::fs::write(out, format!("{}\n", j.to_string_pretty())) {
            Ok(()) => println!("(wrote {} metrics to {})", self.metrics.len(), out.display()),
            Err(e) => eprintln!("warn: BENCH_JSON {}: {e}", out.display()),
        }
    }
}

pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// True when running in smoke mode (`--smoke` argv flag or
/// SHARED_PIM_SMOKE=1): benches shrink to a seconds-long sanity pass.
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("SHARED_PIM_SMOKE").is_ok_and(|v| v != "0" && !v.is_empty())
}

/// Iteration count from env (BENCH_ITERS) with a default; clamped to 2 in
/// smoke mode.
pub fn iters(default: usize) -> usize {
    let n = std::env::var("BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default);
    if smoke() {
        n.min(2)
    } else {
        n
    }
}

/// Workload scale from env (BENCH_SCALE) with a default; forced down to a
/// tiny fraction in smoke mode.
#[allow(dead_code)] // not every bench target scales a workload
pub fn scale(default: f64) -> f64 {
    let s = std::env::var("BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default);
    if smoke() {
        s.min(0.05)
    } else {
        s
    }
}
