//! Fig. 9 bench: normalized IPC across the eight non-PIM workloads.

mod common;

use common::{iters, scale, Bench};
use shared_pim::gem5lite::{trace_for, CopyTech, SystemSim, Workload};
use shared_pim::util::stats::geomean;

fn main() {
    let scale = scale(1.0);
    println!("== bench_gem5 (Fig. 9, scale {scale}) ==");
    println!(
        "{:>10} {:>8} {:>8} {:>11}",
        "workload", "memcpy", "LISA", "Shared-PIM"
    );
    let mut lisa_n = Vec::new();
    let mut sp_n = Vec::new();
    for w in Workload::all() {
        let base = SystemSim::table4(CopyTech::Memcpy).run(&trace_for(*w, scale));
        let lisa = SystemSim::table4(CopyTech::Lisa).run(&trace_for(*w, scale));
        let sp = SystemSim::table4(CopyTech::SharedPim).run(&trace_for(*w, scale));
        let b = base.ipc();
        lisa_n.push(lisa.ipc() / b);
        sp_n.push(sp.ipc() / b);
        println!(
            "{:>10} {:>8.3} {:>8.3} {:>11.3}",
            w.name(),
            1.0,
            lisa.ipc() / b,
            sp.ipc() / b
        );
    }
    println!(
        "geomean: lisa {:.3}, shared-pim {:.3} (paper: SP >= LISA >= memcpy everywhere)",
        geomean(&lisa_n),
        geomean(&sp_n)
    );

    println!("\nsimulator throughput:");
    let trace = trace_for(Workload::Bootup, scale.min(0.25));
    let b = Bench::run(
        format!("gem5-lite bootup trace ({} events)", trace.len()),
        iters(30),
        || {
            std::hint::black_box(
                SystemSim::table4(CopyTech::SharedPim).run(&trace).cycles,
            );
        },
    );
    b.report_throughput(trace.len() as f64, "events");
}
