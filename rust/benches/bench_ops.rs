//! Fig. 7 bench: N-bit add/mul latency matrix + scheduler throughput.

mod common;

use common::{iters, Bench};
use shared_pim::config::DramConfig;
use shared_pim::pipeline::{MovePolicy, Scheduler};
use shared_pim::pluto::{composed_op_dag, WideOp};

fn main() {
    let cfg = DramConfig::table1_ddr4();
    let s = Scheduler::new(&cfg);
    println!("== bench_ops (Fig. 7) ==");
    println!(
        "{:>4} {:>5} {:>12} {:>12} {:>10}",
        "op", "bits", "LISA", "Shared-PIM", "reduction"
    );
    for bits in [16usize, 32, 64, 128] {
        for op in [WideOp::Add { bits }, WideOp::Mul { bits }] {
            let l = s.wide_op_latency_ns(op, MovePolicy::Lisa);
            let sp = s.wide_op_latency_ns(op, MovePolicy::SharedPim);
            println!(
                "{:>4} {:>5} {:>9.1} ns {:>9.1} ns {:>9.1}%",
                op.name(),
                bits,
                l,
                sp,
                (1.0 - sp / l) * 100.0
            );
        }
    }
    println!("paper: 18% @32b add, 31% @32b mul, ~40% (1.4x) @128b\n");

    let dag = composed_op_dag(WideOp::Mul { bits: 128 }, &cfg, &s.tc);
    println!("scheduler throughput ({} nodes):", dag.len());
    let b = Bench::run("schedule 128-bit mul dag (shared-pim)", iters(300), || {
        std::hint::black_box(s.run(&dag, MovePolicy::SharedPim).makespan);
    });
    b.report_throughput(dag.len() as f64, "ops scheduled");
}
