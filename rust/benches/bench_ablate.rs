//! Ablations called out in DESIGN.md §9: shared-row count, BK-bus segment
//! count (energy), broadcast cap, and the NOP-vs-STALL overlap itself.

// The shared harness also carries helpers this target does not use.
#[allow(dead_code)]
mod common;

use common::scale;
use shared_pim::apps::{build_app, App};
use shared_pim::config::DramConfig;
use shared_pim::energy::EnergyModel;
use shared_pim::pipeline::{MovePolicy, Scheduler};

fn main() {
    let sc = scale(0.25);
    println!("== bench_ablate (scale {sc}) ==\n");

    // (a) broadcast fan-out cap: MM uses broadcast-free clusters, so probe
    // with a synthetic broadcast-heavy DAG via max_broadcast sweep on PMM
    println!("broadcast cap sweep (PMM, Shared-PIM):");
    for cap in [1usize, 2, 4, 6] {
        let mut cfg = DramConfig::table1_ddr4();
        cfg.pim.max_broadcast = cap;
        let s = Scheduler::new(&cfg);
        let dag = build_app(App::Pmm, &cfg, &s.tc, sc);
        let r = s.run(&dag, MovePolicy::SharedPim);
        println!("  cap {:>2}: makespan {:>9.2} us, bus ops {}", cap, r.makespan_us(), r.bus_ops);
    }

    // (b) BK-bus segments: energy per bus op scales with the segment count
    println!("\nBK-bus segment sweep (energy of one bus sense):");
    for segs in [1usize, 2, 4, 8] {
        let mut cfg = DramConfig::table1_ddr4();
        cfg.pim.bus_segments = segs;
        let em = EnergyModel::new(&cfg);
        println!(
            "  {} segments: {:>7.2} nJ per BK-SA sense",
            segs, em.e_bus_sense_nj
        );
    }

    // (c) NOP-vs-STALL: the overlap claim isolated from raw copy speed.
    // Run the same DAG with Shared-PIM latencies but LISA-style stalling by
    // comparing against a Shared-PIM run whose bus ops are as slow as LISA
    // moves (slow-bus strawman) and a LISA run with Shared-PIM-fast moves.
    println!("\noverlap ablation (MM):");
    let cfg = DramConfig::table1_ddr4();
    let s = Scheduler::new(&cfg);
    let dag = build_app(App::Mm, &cfg, &s.tc, sc);
    let lisa = s.run(&dag, MovePolicy::Lisa);
    let sp = s.run(&dag, MovePolicy::SharedPim);
    // strawman: stall-free transfers but LISA-class latency
    let mut slow_cfg = cfg.clone();
    slow_cfg.pim.max_broadcast = 1;
    let mut slow = Scheduler::new(&slow_cfg);
    slow.tc.pim.t_gwl_share *= 16; // bus op ~ LISA move latency
    let sp_slowbus = slow.run(&build_app(App::Mm, &slow_cfg, &slow.tc, sc), MovePolicy::SharedPim);
    println!("  pLUTo+LISA              : {:>9.2} us (stall)", lisa.makespan_us());
    println!("  pLUTo+Shared-PIM        : {:>9.2} us (overlap + fast bus)", sp.makespan_us());
    println!(
        "  overlap-only (slow bus) : {:>9.2} us (overlap, LISA-class latency)",
        sp_slowbus.makespan_us()
    );
    println!(
        "  -> overlap alone recovers {:.0}% of the total gain",
        100.0 * (lisa.makespan_us() - sp_slowbus.makespan_us())
            / (lisa.makespan_us() - sp.makespan_us())
    );

    // (d) shared rows per subarray: 2 suffices when transfers are slower
    // than compute; 1 forces staging serialization (modeled as bus-op x2)
    println!("\nshared-row sweep (cfg knob; 2 = paper default):");
    for rows in [1usize, 2, 4] {
        let mut cfg2 = DramConfig::table1_ddr4();
        cfg2.pim.shared_rows_per_subarray = rows;
        let s2 = Scheduler::new(&cfg2);
        let dag2 = build_app(App::Mm, &cfg2, &s2.tc, sc);
        let r = s2.run(&dag2, MovePolicy::SharedPim);
        println!(
            "  {} shared rows: makespan {:>9.2} us (MASA table {} bits/bank)",
            rows,
            r.makespan_us(),
            11 * cfg2.subarrays_per_bank
        );
    }
}
