//! Table II bench: regenerate the inter-subarray copy comparison and time
//! the simulator itself (copies simulated per second).

mod common;

use common::{iters, Bench};
use shared_pim::config::DramConfig;
use shared_pim::energy::EnergyModel;
use shared_pim::movement::{
    BankSim, CopyEngine, CopyRequest, LisaEngine, MemcpyEngine, RowCloneEngine,
    SharedPimEngine,
};

fn main() {
    let cfg = DramConfig::table1_ddr3();
    let em = EnergyModel::new(&cfg);
    println!("== bench_copy (Table II) ==");
    println!(
        "{:<16} {:>12} {:>12} | paper: 1366.25/1363.75/260.5/52.75 ns",
        "engine", "sim latency", "energy"
    );
    let engines: Vec<Box<dyn CopyEngine>> = vec![
        Box::new(MemcpyEngine),
        Box::new(RowCloneEngine),
        Box::new(LisaEngine),
        Box::new(SharedPimEngine::default()),
    ];
    for eng in &engines {
        let mut sim = BankSim::new(&cfg);
        sim.bank.write_row(0, 1, vec![0x5A; cfg.row_bytes]);
        let st = eng.copy(
            &mut sim,
            CopyRequest { src_sa: 0, src_row: 1, dst_sa: 2, dst_row: 3 },
        );
        println!(
            "{:<16} {:>9.2} ns {:>9.3} uJ",
            eng.name(),
            st.latency_ns(),
            em.trace_energy_uj(&st.commands)
        );
    }

    println!("\nsimulator throughput:");
    for eng in &engines {
        let n = iters(200);
        let b = Bench::run(format!("simulate {} copy", eng.name()), n, || {
            let mut sim = BankSim::new(&cfg);
            sim.bank.write_row(0, 1, vec![0x5A; cfg.row_bytes]);
            let st = eng.copy(
                &mut sim,
                CopyRequest { src_sa: 0, src_row: 1, dst_sa: 2, dst_row: 3 },
            );
            std::hint::black_box(st.latency_ps());
        });
        b.report_throughput(1.0, "copies");
    }
}
