//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! timing-checker command throughput, scheduler node throughput, gem5-lite
//! event throughput, and the transient execution (native interpreter
//! always; PJRT additionally when artifacts exist).

mod common;

use common::{iters, smoke, Bench, MetricSink};
use shared_pim::calibrate::{schedule, spec};
use shared_pim::config::DramConfig;
use shared_pim::dram::{Command, TimingChecker};
use shared_pim::gem5lite::{trace_for, CopyTech, SystemSim, Workload};
use shared_pim::pipeline::{MovePolicy, Scheduler};
use shared_pim::pluto::{composed_op_dag, WideOp};

fn main() {
    println!("== bench_hotpath ==");
    let mut sink = MetricSink::from_env();
    let cfg = DramConfig::table1_ddr3();

    // 1) timing checker: ACT/PRE command stream
    let n_cmds = if smoke() { 5_000usize } else { 100_000usize };
    let b = Bench::run("timing-checker ACT/PRE stream", iters(20), || {
        let mut tc = TimingChecker::new(&cfg);
        for i in 0..n_cmds {
            let sa = i % 16;
            let (_, _) = tc.issue_earliest(&Command::Activate { sa, row: i % 510 });
            tc.issue_earliest(&Command::PrechargeSub { sa });
        }
        std::hint::black_box(tc.now());
    });
    let mean = b.report_throughput(2.0 * n_cmds as f64, "commands");
    sink.push("timing_checker_commands_per_sec", 2.0 * n_cmds as f64 / mean, "higher");

    // 2) scheduler: large mul DAG
    let s = Scheduler::new(&DramConfig::table1_ddr4());
    let dag = composed_op_dag(WideOp::Mul { bits: 128 }, &s.cfg, &s.tc);
    let b = Bench::run(
        format!("pipeline scheduler ({} nodes)", dag.len()),
        iters(500),
        || {
            std::hint::black_box(s.run(&dag, MovePolicy::SharedPim).makespan);
        },
    );
    let mean = b.report_throughput(dag.len() as f64, "nodes");
    sink.push("scheduler_nodes_per_sec", dag.len() as f64 / mean, "higher");

    // 3) gem5-lite event loop
    let trace = trace_for(Workload::SpecLike, if smoke() { 0.05 } else { 0.5 });
    let b = Bench::run(
        format!("gem5-lite spec trace ({} events)", trace.len()),
        iters(50),
        || {
            std::hint::black_box(
                SystemSim::table4(CopyTech::SharedPim).run(&trace).cycles,
            );
        },
    );
    let mean = b.report_throughput(trace.len() as f64, "events");
    sink.push("gem5lite_events_per_sec", trace.len() as f64 / mean, "higher");

    // 4) native transient interpreter (artifact-free, always runs)
    let cell_steps = (spec::N_STEPS * spec::N_COLS) as f64;
    let transient_label = |backend: &str| {
        format!("{backend} transient ({} steps x {} cols)", spec::N_STEPS, spec::N_COLS)
    };
    {
        use shared_pim::transient::run_native;
        let st = schedule::initial_state();
        let sc = schedule::full_copy(4);
        let p = schedule::default_params();
        let b = Bench::run(transient_label("native"), iters(5), || {
            std::hint::black_box(run_native(&st, &sc, &p).unwrap().energy[0]);
        });
        let mean = b.report_throughput(cell_steps, "cell-steps");
        sink.push("native_transient_cell_steps_per_sec", cell_steps / mean, "higher");
    }

    // 5) PJRT transient execution (needs artifacts)
    match shared_pim::runtime::Runtime::new("artifacts") {
        Ok(rt) => {
            let exe = rt.transient().expect("compile");
            let st = schedule::initial_state();
            let sc = schedule::full_copy(4);
            let p = schedule::default_params();
            let b = Bench::run(transient_label("PJRT"), iters(5), || {
                std::hint::black_box(exe.run(&st, &sc, &p).unwrap().energy[0]);
            });
            b.report_throughput(cell_steps, "cell-steps");
        }
        Err(e) => println!("(skipping PJRT bench: {e})"),
    }

    sink.write("bench_hotpath");
}
