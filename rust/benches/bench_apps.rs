//! Fig. 8 bench: the five application benchmarks at paper scale (use
//! --quick / BENCH_SCALE to shrink).

mod common;

use common::{iters, scale, Bench};
use shared_pim::apps::{build_app, App};
use shared_pim::config::DramConfig;
use shared_pim::pipeline::{MovePolicy, Scheduler};

fn main() {
    let scale = scale(1.0);
    let cfg = DramConfig::table1_ddr4();
    let s = Scheduler::new(&cfg);
    println!("== bench_apps (Fig. 8, scale {scale}) ==");
    println!(
        "{:>5} {:>12} {:>12} {:>9} {:>11} {:>11} | paper gain",
        "app", "LISA", "Shared-PIM", "gain", "E_L (uJ)", "E_SP (uJ)"
    );
    let paper = [40.0, 44.0, 31.0, 29.0, 29.0];
    for (app, pg) in App::all().iter().zip(paper) {
        let dag = build_app(*app, &cfg, &s.tc, scale);
        let l = s.run(&dag, MovePolicy::Lisa);
        let sp = s.run(&dag, MovePolicy::SharedPim);
        println!(
            "{:>5} {:>9.1} us {:>9.1} us {:>8.1}% {:>11.2} {:>11.2} | {:.0}%",
            app.name(),
            l.makespan_us(),
            sp.makespan_us(),
            (1.0 - sp.makespan as f64 / l.makespan as f64) * 100.0,
            l.transfer_energy_uj,
            sp.transfer_energy_uj,
            pg
        );
    }

    println!("\nsimulator throughput:");
    let dag = build_app(App::Mm, &cfg, &s.tc, scale.min(0.25));
    let b = Bench::run(
        format!("schedule MM dag ({} nodes)", dag.len()),
        iters(50),
        || {
            std::hint::black_box(s.run(&dag, MovePolicy::SharedPim).makespan);
        },
    );
    b.report_throughput(dag.len() as f64, "ops");
}
