//! Edge cases and failure injection across the public API.

use shared_pim::config::{DramConfig, SharedPimConfig};
use shared_pim::dram::{Bank, Command};
use shared_pim::movement::{BankSim, CopyEngine, CopyRequest, SharedPimEngine};
use shared_pim::pipeline::{MovePolicy, OpDag, Scheduler};
use shared_pim::util::json::Json;

#[test]
fn adjacent_subarray_copies_work() {
    // distance-1 edge for every direction
    let cfg = DramConfig::table1_ddr3();
    for (src, dst) in [(0usize, 1usize), (15, 14), (7, 8)] {
        let mut sim = BankSim::new(&cfg);
        let data = vec![0xC3u8; cfg.row_bytes];
        sim.bank.write_row(src, 9, data.clone());
        SharedPimEngine::default().copy(
            &mut sim,
            CopyRequest { src_sa: src, src_row: 9, dst_sa: dst, dst_row: 11 },
        );
        assert_eq!(sim.bank.read_row(dst, 11), data, "{}->{}", src, dst);
    }
}

#[test]
fn copy_overwrites_previous_destination_contents() {
    let cfg = DramConfig::table1_ddr3();
    let mut sim = BankSim::new(&cfg);
    sim.bank.write_row(3, 5, vec![0xFF; cfg.row_bytes]); // stale data
    sim.bank.write_row(0, 1, vec![0x01; cfg.row_bytes]);
    SharedPimEngine::default().copy(
        &mut sim,
        CopyRequest { src_sa: 0, src_row: 1, dst_sa: 3, dst_row: 5 },
    );
    assert_eq!(sim.bank.read_row(3, 5), vec![0x01; cfg.row_bytes]);
}

#[test]
fn empty_dag_schedules_to_zero() {
    let cfg = DramConfig::table1_ddr4();
    let s = Scheduler::new(&cfg);
    let r = s.run(&OpDag::new(), MovePolicy::SharedPim);
    assert_eq!(r.makespan, 0);
    assert_eq!(r.moves, 0);
}

#[test]
fn single_node_dag() {
    let cfg = DramConfig::table1_ddr4();
    let s = Scheduler::new(&cfg);
    let mut dag = OpDag::new();
    dag.compute(0, 1234, &[], "only");
    let r = s.run(&dag, MovePolicy::Lisa);
    assert_eq!(r.makespan, 1234);
}

#[test]
fn degenerate_pim_config_one_shared_row_one_segment() {
    let cfg = DramConfig {
        pim: SharedPimConfig {
            shared_rows_per_subarray: 1,
            bus_segments: 1,
            max_broadcast: 1,
            overlap_act_ns: 4.0,
        },
        ..DramConfig::table1_ddr3()
    };
    let mut sim = BankSim::new(&cfg);
    let data = vec![0x77u8; cfg.row_bytes];
    sim.bank.write_shared(2, 0, data.clone());
    // slot 1 does not exist; slot 0 round-trips
    let (_, _) = SharedPimEngine::bus_transfer(&mut sim, 2, 0, &[(9, 0)]);
    assert_eq!(sim.bank.read_shared(9, 0), data);
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join(format!("spim-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{\"version\": 99}").unwrap();
    let err = shared_pim::runtime::Runtime::new(&dir);
    match err {
        Ok(rt) => {
            // runtime may construct; the spec check must fail
            assert!(shared_pim::calibrate::spec::check_manifest(&rt.manifest).is_err());
        }
        Err(_) => {} // also acceptable: missing fields rejected at load
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_json_parse_errors_not_panics() {
    for bad in ["{\"a\":", "[1,2,", "\"unterminated", "{\"a\" \"b\"}", "tru"] {
        assert!(Json::parse(bad).is_err(), "{:?} should fail", bad);
    }
}

#[test]
fn bank_rejects_wrong_row_size() {
    let mut b = Bank::new(16, 512, 64, 2);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        b.write_row(0, 0, vec![0u8; 63]); // one byte short
    }));
    assert!(r.is_err());
}

#[test]
fn shared_row_addresses_do_not_alias_data_rows() {
    let mut b = Bank::new(16, 512, 64, 2);
    // write to the last data row and both shared slots; all distinct
    b.write_row(0, b.data_rows() - 1, vec![1; 64]);
    b.write_shared(0, 0, vec![2; 64]);
    b.write_shared(0, 1, vec![3; 64]);
    assert_eq!(b.read_row(0, b.data_rows() - 1), vec![1; 64]);
    assert_eq!(b.read_row(0, b.shared_row_addr(0)), vec![2; 64]);
    assert_eq!(b.read_row(0, b.shared_row_addr(1)), vec![3; 64]);
}

#[test]
fn timing_checker_rejects_out_of_order_issue() {
    let cfg = DramConfig::table1_ddr3();
    let mut sim = BankSim::new(&cfg);
    sim.exec(Command::Activate { sa: 0, row: 1 });
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        sim.exec_at(Command::Activate { sa: 0, row: 2 }, 0); // violates tRC
    }));
    assert!(r.is_err(), "timing violation must be caught");
}
