//! Focused coverage for the support substrate: `util::json` (full-grammar
//! round-trips, escapes, nested arrays, NaN/Infinity rejection) and
//! `util::cli` (flags, `--key value` / `--key=value`, subcommands, error
//! paths). These are the pieces every harness entry point leans on.

use shared_pim::util::cli::Args;
use shared_pim::util::json::{obj, Json};
use std::collections::BTreeMap;

fn parse_args(s: &str) -> Args {
    Args::parse(s.split_whitespace().map(String::from))
}

// ---------- util::json ----------

#[test]
fn json_round_trips_escapes() {
    let src = r#"{"s": "tab\t nl\n cr\r quote\" back\\ slash\/ bs\b ff\f unicodeé"}"#;
    let j = Json::parse(src).unwrap();
    let s = j.get("s").and_then(|v| v.as_str()).unwrap();
    assert!(s.contains('\t') && s.contains('\n') && s.contains('\r') && s.contains('"'));
    assert!(s.contains('\\') && s.contains('/') && s.contains('é'));
    assert!(s.contains('\u{8}') && s.contains('\u{c}'), "b and f escapes survive");
    // serialized form (control chars re-escaped) must re-parse identically
    let again = Json::parse(&j.to_string_pretty()).unwrap();
    assert_eq!(j, again);
}

#[test]
fn json_round_trips_nested_arrays() {
    let src = r#"[[1, 2], [3, [4, 5, []]], {"k": [true, null, -2.5e-1]}]"#;
    let j = Json::parse(src).unwrap();
    let arr = j.as_arr().unwrap();
    assert_eq!(arr.len(), 3);
    assert_eq!(arr[0].as_arr().unwrap()[1], Json::Num(2.0));
    let inner = arr[1].as_arr().unwrap()[1].as_arr().unwrap();
    assert_eq!(inner[2], Json::Arr(vec![]));
    let k = arr[2].get("k").unwrap().as_arr().unwrap();
    assert_eq!(k[2], Json::Num(-0.25));
    let again = Json::parse(&j.to_string_pretty()).unwrap();
    assert_eq!(j, again);
}

#[test]
fn json_rejects_nan_and_infinity_literals() {
    for bad in ["NaN", "nan", "Infinity", "-Infinity", "inf", "[1, NaN]", "{\"a\": nan}"] {
        assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
    }
}

#[test]
fn json_serializes_non_finite_numbers_as_null() {
    // JSON has no NaN/inf; the writer must still emit valid JSON
    let j = obj(vec![
        ("nan", Json::Num(f64::NAN)),
        ("inf", Json::Num(f64::INFINITY)),
        ("ninf", Json::Num(f64::NEG_INFINITY)),
        ("ok", Json::Num(1.5)),
    ]);
    let text = j.to_string_pretty();
    let again = Json::parse(&text).unwrap();
    assert_eq!(again.get("nan"), Some(&Json::Null));
    assert_eq!(again.get("inf"), Some(&Json::Null));
    assert_eq!(again.get("ninf"), Some(&Json::Null));
    assert_eq!(again.get("ok"), Some(&Json::Num(1.5)));
}

#[test]
fn json_deep_path_get_and_misses() {
    let j = Json::parse(r#"{"a": {"b": {"c": 7}}}"#).unwrap();
    assert_eq!(j.get("a.b.c").and_then(Json::as_f64), Some(7.0));
    assert_eq!(j.get("a.b.missing"), None);
    assert_eq!(j.get("a.b.c.too_deep"), None);
}

#[test]
fn json_accessor_type_mismatches_are_none() {
    let j = Json::parse(r#"{"n": 3, "s": "x", "b": true, "a": [1]}"#).unwrap();
    assert_eq!(j.get("n").unwrap().as_str(), None);
    assert_eq!(j.get("s").unwrap().as_f64(), None);
    assert_eq!(j.get("b").unwrap().as_arr(), None);
    assert_eq!(j.get("a").unwrap().as_obj(), None);
    // as_u64 rejects negatives and fractions
    assert_eq!(Json::Num(-1.0).as_u64(), None);
    assert_eq!(Json::Num(1.5).as_u64(), None);
    assert_eq!(Json::Num(9.0).as_u64(), Some(9));
}

#[test]
fn json_obj_helper_builds_sorted_map() {
    let j = obj(vec![("z", Json::Num(1.0)), ("a", Json::Bool(false))]);
    let mut expect = BTreeMap::new();
    expect.insert("a".to_string(), Json::Bool(false));
    expect.insert("z".to_string(), Json::Num(1.0));
    assert_eq!(j, Json::Obj(expect));
}

#[test]
fn json_error_reports_position() {
    let err = Json::parse("{\"a\": 1,\n  ?}").unwrap_err();
    assert!(err.pos > 0, "position should point at the bad byte: {err}");
    assert!(err.to_string().contains("json error"));
}

// ---------- util::cli ----------

#[test]
fn cli_subcommand_positionals_and_options() {
    let a = parse_args("exp fig7 extra --scale 0.5 --results=out --no-csv");
    assert_eq!(a.subcommand.as_deref(), Some("exp"));
    assert_eq!(a.positional, vec!["fig7", "extra"]);
    assert_eq!(a.opt("scale"), Some("0.5"));
    assert!((a.opt_f64("scale", 1.0) - 0.5).abs() < 1e-12);
    assert_eq!(a.opt_str("results", "results"), "out");
    assert!(a.flag("no-csv"));
}

#[test]
fn cli_jobs_flag_parses_like_repro_all() {
    let a = parse_args("all --jobs 4");
    assert_eq!(a.subcommand.as_deref(), Some("all"));
    assert_eq!(a.opt_usize("jobs", 1), 4);
    // and the = syntax
    let b = parse_args("all --jobs=8");
    assert_eq!(b.opt_usize("jobs", 1), 8);
}

#[test]
fn cli_error_paths_fall_back_to_defaults() {
    // non-numeric values fall back; missing keys fall back; a flag is not
    // an option and vice versa
    let a = parse_args("all --jobs many --verbose");
    assert_eq!(a.opt_usize("jobs", 3), 3, "unparseable value -> default");
    assert_eq!(a.opt_usize("absent", 7), 7);
    assert!((a.opt_f64("jobs", 1.5) - 1.5).abs() < 1e-12);
    assert!(a.flag("verbose"));
    assert!(!a.flag("jobs"), "--jobs consumed a value, it is not a flag");
    assert_eq!(a.opt("verbose"), None, "bare flag has no value");
}

#[test]
fn cli_no_subcommand_is_none() {
    let a = parse_args("");
    assert_eq!(a.subcommand, None);
    assert!(a.positional.is_empty());
    assert!(!a.flag("anything"));
}

#[test]
fn cli_double_dash_values_stay_flags() {
    // `--a --b value`: --a must not swallow --b as its value
    let a = parse_args("x --a --b value --c=1 --d");
    assert!(a.flag("a"));
    assert_eq!(a.opt("b"), Some("value"));
    assert_eq!(a.opt("c"), Some("1"));
    assert!(a.flag("d"), "trailing flag with no value");
}
