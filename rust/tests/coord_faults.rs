//! Fault-injection coverage of the network coordinator (`repro coord`) and
//! its remote workers, driven through real `repro` subprocesses:
//!
//! - two concurrent `--coord` workers drain one coordinator and the remote
//!   merge is byte-identical to a single-process `repro all` (and to a
//!   directory-protocol merge of the same queue);
//! - a worker killed mid-lease has its job swept back and recomputed, and
//!   the merge is still byte-identical;
//! - the coordinator killed mid-drain makes workers fail cleanly (local
//!   cache state intact), and a restarted coordinator on the same queue
//!   directory recovers the orphaned claims and finishes the drain;
//! - a corrupted remote cache entry is rejected and recomputed — never
//!   replayed — while the intact entries produce remote hits on a warm
//!   second drain.

use shared_pim::coordinator::{http_get, http_post};
use shared_pim::util::json::Json;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spim-cf-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn init_queue(queue: &Path, suite: &str, artifacts: Option<&Path>) {
    let mut cmd = repro();
    cmd.args(["queue", "init", "--suite", suite, "--scale", "0.05", "--no-csv", "--no-cache"])
        .arg("--queue")
        .arg(queue);
    if let Some(a) = artifacts {
        cmd.arg("--artifacts").arg(a);
    }
    let out = cmd.output().expect("queue init runs");
    assert!(out.status.success(), "queue init failed: {}", String::from_utf8_lossy(&out.stderr));
}

/// A `repro coord` subprocess bound to port 0; the address comes from the
/// stdout announce line. Killed on drop so a failing test never leaks it.
struct Coord {
    child: Child,
    addr: String,
}

impl Coord {
    fn start(queue: &Path, lease_secs: u64, cache: Option<&Path>) -> Coord {
        let mut cmd = repro();
        cmd.args(["coord", "--addr", "127.0.0.1:0"])
            .arg("--lease-secs")
            .arg(lease_secs.to_string())
            .arg("--queue")
            .arg(queue);
        match cache {
            Some(c) => {
                cmd.arg("--cache").arg(c);
            }
            None => {
                cmd.arg("--no-cache");
            }
        }
        let mut child = cmd
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn coordinator");
        let stdout = child.stdout.take().expect("coordinator stdout");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("read announce line");
        let addr = line
            .trim()
            .strip_prefix("coord: listening on http://")
            .unwrap_or_else(|| panic!("unexpected announce line {line:?}"))
            .to_string();
        Coord { child, addr }
    }

    fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    fn status(&self) -> Json {
        let resp = http_get(&self.addr, "/status").expect("GET /status");
        assert_eq!(resp.status, 200, "status: {}", resp.body);
        Json::parse(&resp.body).expect("status parses")
    }

    /// Graceful stop: POST /shutdown, then require a clean exit.
    fn shutdown(mut self) {
        let resp = http_post(&self.addr, "/shutdown", "").expect("POST /shutdown");
        assert_eq!(resp.status, 200);
        let status = self.child.wait().expect("coordinator exits");
        assert!(status.success(), "coordinator exited uncleanly after /shutdown");
    }

    /// Hard kill (the mid-drain crash injection).
    fn kill(mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Coord {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn wait_until(what: &str, secs: u64, mut probe: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !probe() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Parse the `remote cache: hits N, published M` stderr line of a
/// `--coord` worker.
fn remote_cache_counts(stderr: &str) -> (u64, u64) {
    for line in stderr.lines() {
        if let Some(rest) = line.strip_prefix("remote cache: hits ") {
            let (h, p) = rest.split_once(", published ").expect("remote cache line shape");
            return (h.trim().parse().unwrap(), p.trim().parse().unwrap());
        }
    }
    panic!("no `remote cache:` line in worker stderr:\n{stderr}");
}

#[test]
fn two_coord_workers_drain_one_queue_and_merge_matches_repro_all() {
    let dir = tmpdir("fanout");
    let queue = dir.join("queue");
    let artifacts = dir.join("artifacts");
    init_queue(&queue, "all", Some(&artifacts));
    let coord = Coord::start(&queue, 60, None);

    let workers: Vec<_> = (0..2)
        .map(|i| {
            repro()
                .args(["queue", "work", "--scale", "0.05", "--no-csv", "--no-cache"])
                .args(["--coord", &coord.url()])
                .args(["--worker-id", &format!("net-{i}")])
                .arg("--artifacts")
                .arg(&artifacts)
                .stdout(Stdio::piped())
                .stderr(Stdio::piped())
                .spawn()
                .expect("spawn worker")
        })
        .collect();
    let mut executed_total = 0u64;
    for w in workers {
        let out = w.wait_with_output().expect("worker exits");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(out.status.success(), "worker failed: {stderr}");
        assert!(out.stdout.is_empty(), "queue work must keep stdout empty");
        // both workers claimed through the same coordinator: together they
        // executed every job exactly once
        let summary = stderr
            .lines()
            .find(|l| l.starts_with("worker net-") && l.contains(" jobs in "))
            .unwrap_or_else(|| panic!("no worker summary in stderr:\n{stderr}"));
        let jobs: u64 = summary
            .split(": ")
            .nth(1)
            .and_then(|s| s.split(' ').next())
            .and_then(|n| n.parse().ok())
            .unwrap_or_else(|| panic!("unparsable summary {summary:?}"));
        executed_total += jobs;
    }
    let n_jobs = coord
        .status()
        .get("queue.n_jobs")
        .and_then(Json::as_u64)
        .expect("status carries n_jobs");
    assert_eq!(executed_total, n_jobs, "jobs must be executed exactly once in total");

    let merged = repro()
        .args(["queue", "merge", "--no-csv", "--no-cache"])
        .args(["--coord", &coord.url()])
        .output()
        .expect("remote merge runs");
    assert!(merged.status.success(), "merge failed: {}", String::from_utf8_lossy(&merged.stderr));

    let single = repro()
        .args(["all", "--jobs", "2", "--scale", "0.05", "--no-csv", "--no-cache"])
        .arg("--artifacts")
        .arg(&artifacts)
        .output()
        .expect("single-process all");
    assert!(single.status.success());
    assert_eq!(
        String::from_utf8_lossy(&merged.stdout),
        String::from_utf8_lossy(&single.stdout),
        "remote merge must be byte-identical to the single-process run"
    );

    // the coordinator's queue directory stayed a valid directory-protocol
    // queue: a plain `repro queue merge --queue` agrees byte-for-byte
    let dir_merge = repro()
        .args(["queue", "merge", "--no-csv", "--no-cache"])
        .arg("--queue")
        .arg(&queue)
        .output()
        .expect("directory merge runs");
    assert!(dir_merge.status.success());
    assert_eq!(
        String::from_utf8_lossy(&dir_merge.stdout),
        String::from_utf8_lossy(&single.stdout),
        "directory merge of a coordinator-drained queue diverged"
    );

    coord.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn killing_a_coord_worker_mid_lease_requeues_and_merge_still_matches() {
    let dir = tmpdir("worker-crash");
    let queue = dir.join("queue");
    init_queue(&queue, "sweep", None);
    let coord = Coord::start(&queue, 1, None);

    // the doomed worker claims one job, then plays dead (stall hook: no
    // heartbeat ever starts, so its 1 s coordinator lease just ages out)
    let mut doomed = repro()
        .args(["queue", "work", "--scale", "0.05", "--no-csv", "--no-cache"])
        .args(["--coord", &coord.url()])
        .args(["--worker-id", "doomed"])
        .env("SHARED_PIM_QUEUE_STALL_MS", "120000")
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn doomed worker");
    wait_until("doomed worker to claim a job", 60, || {
        coord.status().get("counts.claimed").and_then(Json::as_u64).unwrap_or(0) >= 1
    });
    doomed.kill().expect("kill doomed worker");
    let _ = doomed.wait();

    // a healthy worker drains the queue: the claim-miss sweep requeues the
    // expired lease and the crashed job is recomputed
    let rescue = repro()
        .args(["queue", "work", "--scale", "0.05", "--no-csv", "--no-cache"])
        .args(["--coord", &coord.url()])
        .args(["--worker-id", "rescuer"])
        .output()
        .expect("rescue worker runs");
    assert!(
        rescue.status.success(),
        "rescue worker failed: {}",
        String::from_utf8_lossy(&rescue.stderr)
    );
    let requeues = coord
        .status()
        .get("counters.requeues")
        .and_then(Json::as_u64)
        .expect("status carries requeues");
    assert!(requeues >= 1, "the crashed worker's lease was never swept");

    let merged = repro()
        .args(["queue", "merge", "--no-csv", "--no-cache"])
        .args(["--coord", &coord.url()])
        .output()
        .expect("remote merge runs");
    assert!(
        merged.status.success(),
        "merge after crash failed: {}",
        String::from_utf8_lossy(&merged.stderr)
    );
    let single = repro()
        .args(["sweep", "--jobs", "2", "--scale", "0.05", "--no-csv", "--no-cache"])
        .output()
        .expect("single-process sweep");
    assert!(single.status.success());
    assert_eq!(
        String::from_utf8_lossy(&merged.stdout),
        String::from_utf8_lossy(&single.stdout),
        "post-crash remote merge must still be byte-identical"
    );

    coord.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn killing_the_coordinator_mid_drain_degrades_cleanly_and_a_restart_recovers() {
    let dir = tmpdir("coord-crash");
    let queue = dir.join("queue");
    let local_cache = dir.join("worker-cache");
    init_queue(&queue, "sweep", None);
    let coord = Coord::start(&queue, 60, None);

    // a slowed-down worker (300 ms per claim) so the coordinator dies with
    // the drain genuinely in progress
    let worker = repro()
        .args(["queue", "work", "--scale", "0.05", "--no-csv"])
        .args(["--coord", &coord.url()])
        .args(["--worker-id", "survivor"])
        .arg("--cache")
        .arg(&local_cache)
        .env("SHARED_PIM_QUEUE_STALL_MS", "300")
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn worker");
    wait_until("first done record", 60, || {
        coord.status().get("counts.done").and_then(Json::as_u64).unwrap_or(0) >= 1
    });
    coord.kill();

    // the worker gives up after bounded retries with a clean error naming
    // the coordinator — no panic, no corrupted local state
    let out = worker.wait_with_output().expect("worker exits");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "worker must fail once the coordinator is gone");
    assert!(
        stderr.contains("coordinator"),
        "worker error must name the unreachable coordinator:\n{stderr}"
    );

    // its local cache survived the crash intact: entries parse and none
    // are stale or unreadable
    let stats = repro()
        .args(["cache", "stats"])
        .arg("--cache")
        .arg(&local_cache)
        .output()
        .expect("cache stats runs");
    assert!(stats.status.success());
    let text = String::from_utf8_lossy(&stats.stdout);
    assert!(text.contains("suite sweep"), "local cache lost its entries: {text}");
    assert!(text.contains("0 stale-model, 0 unreadable"), "local cache corrupted: {text}");

    // a restarted coordinator on the same queue directory requeues the
    // orphaned claims; a fresh worker (same warm local cache) finishes
    let coord2 = Coord::start(&queue, 60, None);
    let finish = repro()
        .args(["queue", "work", "--scale", "0.05", "--no-csv"])
        .args(["--coord", &coord2.url()])
        .args(["--worker-id", "finisher"])
        .arg("--cache")
        .arg(&local_cache)
        .output()
        .expect("finishing worker runs");
    assert!(
        finish.status.success(),
        "finishing worker failed: {}",
        String::from_utf8_lossy(&finish.stderr)
    );

    let merged = repro()
        .args(["queue", "merge", "--no-csv", "--no-cache"])
        .args(["--coord", &coord2.url()])
        .output()
        .expect("remote merge runs");
    assert!(
        merged.status.success(),
        "merge after coordinator crash failed: {}",
        String::from_utf8_lossy(&merged.stderr)
    );
    let single = repro()
        .args(["sweep", "--jobs", "2", "--scale", "0.05", "--no-csv", "--no-cache"])
        .output()
        .expect("single-process sweep");
    assert!(single.status.success());
    assert_eq!(
        String::from_utf8_lossy(&merged.stdout),
        String::from_utf8_lossy(&single.stdout),
        "merge across a coordinator crash must be byte-identical"
    );

    coord2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_remote_cache_entry_is_recomputed_and_intact_entries_hit() {
    let dir = tmpdir("remote-cache");
    let remote_cache = dir.join("coord-cache");
    let q1 = dir.join("q1");
    init_queue(&q1, "sweep", None);
    let coord = Coord::start(&q1, 60, Some(&remote_cache));

    // first drain with a fresh local cache publishes every entry remotely
    let w1 = repro()
        .args(["queue", "work", "--scale", "0.05", "--no-csv"])
        .args(["--coord", &coord.url()])
        .args(["--worker-id", "publisher"])
        .arg("--cache")
        .arg(&dir.join("local-1"))
        .output()
        .expect("publishing worker runs");
    let w1_err = String::from_utf8_lossy(&w1.stderr);
    assert!(w1.status.success(), "publishing worker failed: {w1_err}");
    let (hits1, published1) = remote_cache_counts(&w1_err);
    assert_eq!(hits1, 0, "a cold remote cache cannot hit");
    assert!(published1 >= 1, "worker published nothing: {w1_err}");
    coord.shutdown();

    // corrupt one published entry in place
    let victim = std::fs::read_dir(&remote_cache)
        .expect("remote cache dir")
        .flatten()
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|e| e == "json"))
        .expect("at least one published entry");
    std::fs::write(&victim, "{truncated garbage").unwrap();

    // a second drain (fresh queue, fresh local cache, same remote cache):
    // the corrupt entry is rejected and recomputed, every other one hits
    let q2 = dir.join("q2");
    init_queue(&q2, "sweep", None);
    let coord2 = Coord::start(&q2, 60, Some(&remote_cache));
    let w2 = repro()
        .args(["queue", "work", "--scale", "0.05", "--no-csv"])
        .args(["--coord", &coord2.url()])
        .args(["--worker-id", "fetcher"])
        .arg("--cache")
        .arg(&dir.join("local-2"))
        .output()
        .expect("fetching worker runs");
    let w2_err = String::from_utf8_lossy(&w2.stderr);
    assert!(w2.status.success(), "fetching worker failed: {w2_err}");
    assert!(
        w2_err.contains("is corrupt"),
        "the corrupted entry was not flagged: {w2_err}"
    );
    let (hits2, published2) = remote_cache_counts(&w2_err);
    assert!(hits2 >= 1, "warm drain saw no remote hits: {w2_err}");
    assert_eq!(hits2, published1 - 1, "every intact entry must hit");
    assert!(published2 >= 1, "the recomputed entry must be republished: {w2_err}");

    // and the replayed-from-cache drain still merges byte-identically
    let merged = repro()
        .args(["queue", "merge", "--no-csv", "--no-cache"])
        .args(["--coord", &coord2.url()])
        .output()
        .expect("remote merge runs");
    assert!(merged.status.success(), "{}", String::from_utf8_lossy(&merged.stderr));
    let single = repro()
        .args(["sweep", "--jobs", "2", "--scale", "0.05", "--no-csv", "--no-cache"])
        .output()
        .expect("single-process sweep");
    assert!(single.status.success());
    assert_eq!(
        String::from_utf8_lossy(&merged.stdout),
        String::from_utf8_lossy(&single.stdout),
        "cache-replayed merge must be byte-identical"
    );

    coord2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
