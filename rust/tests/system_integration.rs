//! Cross-module integration tests (no artifacts required): movement engines
//! against the timing checker and MASA tracker, energy accounting, config
//! round-trips. Extended with pipeline/apps checks as those modules land.

use shared_pim::config::DramConfig;
use shared_pim::energy::EnergyModel;
use shared_pim::movement::{
    BankSim, CopyEngine, CopyRequest, LisaEngine, MemcpyEngine, RowCloneEngine,
    SharedPimEngine,
};

#[test]
fn table2_shape_headline() {
    // The paper's headline Table II shape: Shared-PIM ~5x faster and ~1.2x
    // less energy than LISA; both orders of magnitude beyond memcpy/RC.
    let cfg = DramConfig::table1_ddr3();
    let em = EnergyModel::new(&cfg);
    let run = |eng: &dyn CopyEngine| {
        let mut sim = BankSim::new(&cfg);
        sim.bank.write_row(0, 1, vec![0xAA; cfg.row_bytes]);
        let st = eng.copy(
            &mut sim,
            CopyRequest { src_sa: 0, src_row: 1, dst_sa: 2, dst_row: 3 },
        );
        (st.latency_ns(), em.trace_energy_uj(&st.commands))
    };
    let (l_mem, _) = run(&MemcpyEngine);
    let (l_rc, _) = run(&RowCloneEngine);
    let (l_lisa, e_lisa) = run(&LisaEngine);
    let (l_sp, e_sp) = run(&SharedPimEngine::default());

    // paper: 1366.25 / 1363.75 / 260.5 / 52.75 ns
    assert!((1200.0..1550.0).contains(&l_mem), "memcpy {}", l_mem);
    assert!((1200.0..1550.0).contains(&l_rc), "rc {}", l_rc);
    assert!((230.0..290.0).contains(&l_lisa), "lisa {}", l_lisa);
    assert!((48.0..58.0).contains(&l_sp), "shared-pim {}", l_sp);
    let speedup = l_lisa / l_sp;
    assert!((4.0..6.0).contains(&speedup), "paper ~5x, got {:.2}", speedup);
    let esave = e_lisa / e_sp;
    assert!((1.05..2.0).contains(&esave), "paper ~1.2x, got {:.2}", esave);
}

#[test]
fn concurrent_compute_and_transfer_is_real() {
    // While a Shared-PIM bus transfer runs, issue ACTIVATEs on uninvolved
    // subarrays — they must all fit inside the transfer window (modulo the
    // tRRD/tFAW issue constraints), which is the paper's core enablement.
    let cfg = DramConfig::table1_ddr3();
    let mut sim = BankSim::new(&cfg);
    sim.bank.write_shared(0, 0, vec![1; cfg.row_bytes]);
    let (t0, end) = SharedPimEngine::bus_transfer(&mut sim, 0, 0, &[(15, 1)]);
    // unrelated subarrays' local SAs stay free for the whole window
    use shared_pim::dram::Command;
    for sa in [5usize, 9, 12] {
        assert!(sim.timing.sa_free_at(sa, t0), "sa {} blocked at start", sa);
        assert!(sim.timing.sa_free_at(sa, (t0 + end) / 2), "sa {} blocked mid", sa);
    }
    let mut sim2 = BankSim::new(&cfg);
    sim2.bank.write_row(0, 1, vec![2; cfg.row_bytes]);
    // contrast: during a LISA copy the spanned subarrays cannot activate
    let st = LisaEngine.copy(
        &mut sim2,
        CopyRequest { src_sa: 0, src_row: 1, dst_sa: 3, dst_row: 0 },
    );
    let e_mid = sim2.timing.earliest(&Command::Activate { sa: 2, row: 0 });
    assert!(
        e_mid >= st.end.saturating_sub(shared_pim::dram::ns_to_ps(20.0)),
        "LISA should stall subarray 2 until near the copy end"
    );
    let _ = end;
}

#[test]
fn ddr4_timing_also_reproduces_shape() {
    let cfg = DramConfig::table1_ddr4();
    let mut sim = BankSim::new(&cfg);
    sim.bank.write_row(0, 1, vec![3; cfg.row_bytes]);
    let sp = SharedPimEngine::default()
        .copy(&mut sim, CopyRequest { src_sa: 0, src_row: 1, dst_sa: 4, dst_row: 2 })
        .latency_ns();
    let mut sim2 = BankSim::new(&cfg);
    sim2.bank.write_row(0, 1, vec![3; cfg.row_bytes]);
    let lisa = LisaEngine
        .copy(&mut sim2, CopyRequest { src_sa: 0, src_row: 1, dst_sa: 4, dst_row: 2 })
        .latency_ns();
    assert!(lisa / sp > 3.0, "DDR4: lisa {} vs sp {}", lisa, sp);
}
