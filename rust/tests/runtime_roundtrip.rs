//! Integration test: the AOT-compiled transient artifact loads through PJRT
//! and reproduces the physics the python suite validated — the numeric
//! round-trip across the python/rust boundary.
//!
//! Requires `make artifacts` (skips cleanly if artifacts/ is absent, e.g. in
//! a bare checkout).

use shared_pim::calibrate::{run_calibration, schedule, spec};
use shared_pim::config::DramConfig;
use shared_pim::runtime::{PjrtBackend, TransientBackend};
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("transient.hlo.txt").exists() && dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn transient_artifact_reproduces_copy_physics() {
    let Some(dir) = artifact_dir() else { return };
    // PjrtBackend::new validates the manifest against the compiled-in spec
    // before compiling transient.hlo.txt
    let backend = PjrtBackend::new(&dir).expect("pjrt backend");

    let r = backend
        .run(
            &schedule::initial_state(),
            &schedule::full_copy(4),
            &schedule::default_params(),
        )
        .expect("execute");

    let vdd = spec::VDD;
    // every '1' column reached all four destinations; '0' columns stayed low
    for c in 0..r.n_cols {
        let one = c % 2 == 0;
        for k in 0..4 {
            let v = r.state_of(c, spec::SV_DST0 + k);
            if one {
                assert!(v > 0.9 * vdd, "col {} dst {} = {}", c, k, v);
            } else {
                assert!(v < 0.1 * vdd, "col {} dst {} = {}", c, k, v);
            }
        }
    }
    // untouched broadcast slots stay at 0
    for c in 0..r.n_cols {
        assert!(r.state_of(c, spec::SV_DST0 + 5).abs() < 0.05);
    }
    // energy accumulated and waveform shaped as expected
    assert!(r.energy.iter().all(|&e| e > 0.0));
    assert_eq!(r.waveform.len(), r.n_outer * r.n_state);
}

#[test]
fn calibration_validates_jedec_and_broadcast() {
    let Some(dir) = artifact_dir() else { return };
    let backend = PjrtBackend::new(&dir).expect("pjrt backend");
    let cfg = DramConfig::table1_ddr3();
    let cal = run_calibration(&backend, &cfg).expect("calibration");

    assert!(cal.jedec_ok, "circuit must fit JEDEC windows: {:?}", cal);
    // paper: broadcast to 4 within DDR timing; 5-6 feasible but uncapped
    assert!(cal.max_broadcast >= 4, "max broadcast {}", cal.max_broadcast);
    // settle times grow with fan-out
    let s = &cal.broadcast_settle_ns;
    assert!(s[0] <= s[3] + 1e-9, "settle must grow: {:?}", s);
    // sense within a tRCD-class window
    assert!(cal.t_sense_local_ns < 14.0, "{}", cal.t_sense_local_ns);
    assert!(cal.t_bus_sense_ns < 14.0, "{}", cal.t_bus_sense_ns);
    assert!(cal.t_gwl_share_ns < 8.0, "{}", cal.t_gwl_share_ns);

    // save + reload
    cal.save(&dir).expect("save calibration");
    let again = shared_pim::calibrate::Calibration::load(&dir).expect("load");
    assert_eq!(again.max_broadcast, cal.max_broadcast);
}
