//! End-to-end multi-process sharding: spawn the real `repro` binary once per
//! shard (true separate OS processes, running concurrently), merge the
//! manifests with `repro shard merge`, and require the merged stdout to be
//! byte-identical to a single-process run of the same suite. Also drives
//! the `repro gate` CLI both ways (identity pass, injected regression) and
//! the merge-time config-digest rejection.

use shared_pim::util::json::Json;
use std::path::PathBuf;
use std::process::{Command, Stdio};

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spim-shard-it-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn multi_process_shard_merge_is_byte_identical_to_single_process() {
    let dir = tmpdir("sweep");
    let total = 3usize;

    // fan out: one OS process per shard, all running at once
    let children: Vec<_> = (0..total)
        .map(|i| {
            let manifest = dir.join(format!("s{i}.json"));
            repro()
                .args(["shard", "run", "--suite", "sweep", "--scale", "0.05", "--no-csv"])
                .arg("--no-cache")
                .arg("--shard")
                .arg(format!("{i}/{total}"))
                .arg("--manifest-out")
                .arg(&manifest)
                .env("SHARED_PIM_JOBS", "2")
                .stdout(Stdio::piped())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn shard process")
        })
        .collect();
    for child in children {
        let out = child.wait_with_output().expect("shard process exits");
        assert!(out.status.success(), "shard run failed");
        assert!(out.stdout.is_empty(), "shard run must keep stdout empty for clean merges");
    }

    // merge the three manifests back into one report
    let merged = repro()
        .args(["shard", "merge"])
        .args((0..total).map(|i| dir.join(format!("s{i}.json"))))
        .arg("--no-csv")
        .output()
        .expect("merge runs");
    assert!(
        merged.status.success(),
        "merge failed: {}",
        String::from_utf8_lossy(&merged.stderr)
    );

    // flag-before-paths: `--no-csv` is declared as a boolean flag to the
    // parser, so it never swallows the first manifest path as its value
    let merged_flag_first = repro()
        .args(["shard", "merge", "--no-csv"])
        .args((0..total).map(|i| dir.join(format!("s{i}.json"))))
        .output()
        .expect("merge runs");
    assert!(
        merged_flag_first.status.success(),
        "flag-first merge failed: {}",
        String::from_utf8_lossy(&merged_flag_first.stderr)
    );
    assert_eq!(
        String::from_utf8_lossy(&merged_flag_first.stdout),
        String::from_utf8_lossy(&merged.stdout),
        "flag position must not change the merged report"
    );

    // the reference: the same suite in a single process (sweep rows are
    // scale-independent, so the merged report matches at any scale; pin it
    // anyway for symmetry with the shard runs)
    let single = repro()
        .args(["sweep", "--jobs", "2", "--scale", "0.05", "--no-csv", "--no-cache"])
        .output()
        .expect("single-process run");
    assert!(single.status.success());
    assert!(!single.stdout.is_empty());
    assert_eq!(
        String::from_utf8_lossy(&merged.stdout),
        String::from_utf8_lossy(&single.stdout),
        "merged shard report must be byte-identical to the single-process run"
    );
}

/// The `all` suite — including fig5, which now runs unconditionally on the
/// auto-selected transient backend instead of self-skipping — must shard
/// and merge byte-identically to a single process. This is the test that
/// keeps the calibration/fig5 path inside the determinism contract.
#[test]
fn all_suite_shard_merge_is_byte_identical_and_includes_fig5() {
    let dir = tmpdir("all-fig5");
    // shared artifact dir (fig5 writes calibration.json into it)
    let artifacts = dir.join("artifacts");
    let total = 2usize;

    let children: Vec<_> = (0..total)
        .map(|i| {
            repro()
                .args(["shard", "run", "--suite", "all", "--scale", "0.05", "--no-csv"])
                .arg("--no-cache")
                .arg("--artifacts")
                .arg(&artifacts)
                .arg("--shard")
                .arg(format!("{i}/{total}"))
                .arg("--manifest-out")
                .arg(dir.join(format!("a{i}.json")))
                .env("SHARED_PIM_JOBS", "2")
                .stdout(Stdio::piped())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn shard process")
        })
        .collect();
    for child in children {
        let out = child.wait_with_output().expect("shard process exits");
        assert!(out.status.success(), "all-suite shard run failed");
        assert!(out.stdout.is_empty(), "shard run must keep stdout empty");
    }

    let merged = repro()
        .args(["shard", "merge"])
        .args((0..total).map(|i| dir.join(format!("a{i}.json"))))
        .arg("--no-csv")
        .output()
        .expect("merge runs");
    assert!(
        merged.status.success(),
        "merge failed: {}",
        String::from_utf8_lossy(&merged.stderr)
    );

    let single = repro()
        .args(["all", "--jobs", "2", "--scale", "0.05", "--no-csv", "--no-cache"])
        .arg("--artifacts")
        .arg(&artifacts)
        .output()
        .expect("single-process all");
    assert!(single.status.success());

    let m = String::from_utf8_lossy(&merged.stdout);
    assert_eq!(
        m,
        String::from_utf8_lossy(&single.stdout),
        "merged all-suite report must be byte-identical to the single-process run"
    );
    assert!(
        m.contains("Fig. 5 — Shared-PIM broadcast transient"),
        "fig5 waveform table missing from the merged report"
    );
    assert!(m.contains("transient backend"), "fig5 must record its backend");
    assert!(!m.contains("skipped"), "fig5 must no longer self-skip: {m}");
}

#[test]
fn merge_rejects_shards_from_mismatched_configs() {
    let dir = tmpdir("mismatch");
    for (i, scale) in [(0usize, "0.05"), (1usize, "0.1")] {
        let out = repro()
            .args(["shard", "run", "--suite", "sweep-banks", "--no-csv", "--no-cache"])
            .arg("--shard")
            .arg(format!("{i}/2"))
            .args(["--scale", scale, "--jobs", "2"])
            .arg("--manifest-out")
            .arg(dir.join(format!("m{i}.json")))
            .output()
            .expect("shard run");
        assert!(out.status.success());
    }
    let merged = repro()
        .args(["shard", "merge"])
        .arg(dir.join("m0.json"))
        .arg(dir.join("m1.json"))
        .arg("--no-csv")
        .output()
        .expect("merge runs");
    assert_eq!(merged.status.code(), Some(2), "mismatched configs must be rejected");
    let err = String::from_utf8_lossy(&merged.stderr);
    assert!(err.contains("mismatched") || err.contains("digest"), "stderr: {err}");
}

#[test]
fn gate_cli_passes_identity_and_fails_injected_slowdown() {
    let dir = tmpdir("gate");
    let report = dir.join("bs.json");
    let out = repro()
        .args(["sweep-banks", "--jobs", "2", "--scale", "0.05", "--no-csv", "--no-cache"])
        .arg("--bench-out")
        .arg(&report)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .status()
        .expect("sweep-banks runs");
    assert!(out.success());

    // identity: a report gates cleanly against itself at any tight tolerance
    let ok = repro()
        .args(["gate", "--tol-pct", "0.1"])
        .arg("--baseline")
        .arg(&report)
        .arg("--current")
        .arg(&report)
        .output()
        .expect("gate runs");
    assert!(
        ok.status.success(),
        "identity gate must pass: {}",
        String::from_utf8_lossy(&ok.stderr)
    );
    assert!(String::from_utf8_lossy(&ok.stdout).contains("Perf gate"));

    // inject a 10% slowdown into every point and expect exit code 1
    let text = std::fs::read_to_string(&report).unwrap();
    let mut j = Json::parse(&text).expect("report parses");
    if let Json::Obj(o) = &mut j {
        if let Some(Json::Arr(pts)) = o.get_mut("points") {
            for p in pts {
                if let Json::Obj(po) = p {
                    if let Some(Json::Num(m)) = po.get_mut("makespan_ns") {
                        *m *= 1.1;
                    }
                }
            }
        }
    }
    let slow = dir.join("bs_slow.json");
    std::fs::write(&slow, j.to_string_pretty()).unwrap();
    let fail = repro()
        .args(["gate", "--tol-pct", "2"])
        .arg("--baseline")
        .arg(&report)
        .arg("--current")
        .arg(&slow)
        .output()
        .expect("gate runs");
    assert_eq!(fail.status.code(), Some(1), "10% slowdown must trip a 2% gate");
    let err = String::from_utf8_lossy(&fail.stderr);
    assert!(err.contains("regressions"), "stderr: {err}");
    // the failure message must name the baseline and the tolerance, so a CI
    // log is actionable without reconstructing the invocation
    assert!(
        err.contains(&report.display().to_string()),
        "failure must name the baseline path: {err}"
    );
    assert!(err.contains("tolerance 2%"), "failure must state the tolerance: {err}");

    // a negative tolerance is rejected up front (it would otherwise make
    // every |drift| > tol comparison true/false in surprising ways)
    for bad in ["-1", "nan", "inf"] {
        let out = repro()
            .args(["gate", "--tol-pct", bad])
            .arg("--baseline")
            .arg(&report)
            .arg("--current")
            .arg(&report)
            .output()
            .expect("gate runs");
        assert_eq!(out.status.code(), Some(2), "--tol-pct {bad} must be a usage error");
        assert!(
            String::from_utf8_lossy(&out.stderr).contains("bad --tol-pct"),
            "stderr must explain the rejection"
        );
    }
}

#[test]
fn shared_pim_jobs_env_pins_and_clamps_worker_count() {
    // env wiring is tested through real subprocesses (mutating the test
    // binary's own environment would race other threads' getenv); the
    // batch summary on stderr reports the worker count actually used
    let run = |jobs_env: &str| -> String {
        let out = repro()
            .args(["sweep", "--scale", "0.05", "--no-csv", "--no-cache"])
            .env("SHARED_PIM_JOBS", jobs_env)
            .output()
            .expect("sweep runs");
        assert!(out.status.success());
        String::from_utf8_lossy(&out.stderr).to_string()
    };
    assert!(run("3").contains(" on 3 workers"), "override must pin the pool size");
    assert!(run("0").contains(" on 1 workers"), "zero must clamp to one worker");
    assert!(run("-2").contains(" on 1 workers"), "negative must clamp to one worker");
}

#[test]
fn shard_run_validates_its_arguments() {
    // bad spec: index >= total
    let out = repro()
        .args(["shard", "run", "--shard", "4/4", "--suite", "sweep"])
        .output()
        .expect("repro runs");
    assert_eq!(out.status.code(), Some(2));

    // unknown suite
    let out = repro()
        .args(["shard", "run", "--shard", "0/2", "--suite", "nope"])
        .output()
        .expect("repro runs");
    assert_eq!(out.status.code(), Some(2));

    // unknown shard subcommand
    let out = repro().args(["shard", "frobnicate"]).output().expect("repro runs");
    assert_eq!(out.status.code(), Some(2));
}
