//! End-to-end coverage of the incremental job cache and the filesystem work
//! queue, driven through real `repro` subprocesses:
//!
//! - two concurrent `repro queue work` processes race over one queue and
//!   the merge is byte-identical to a single-process `repro all`;
//! - a worker killed mid-lease (simulated hang via the stall hook) has its
//!   claim requeued by a second worker, and the merge is still identical;
//! - a fully warm `repro shard run` over the `all` suite reports 100%
//!   cache hits and merges byte-identically to the cold run that primed it;
//! - a warm `repro sweep-banks` re-run reports zero misses and reproduces
//!   both the stdout report and the bench JSON byte-for-byte (what the CI
//!   warm-cache job asserts).

use shared_pim::util::json::Json;
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spim-qc-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn two_worker_queue_race_merges_byte_identical_to_repro_all() {
    let dir = tmpdir("race");
    let queue = dir.join("queue");
    let artifacts = dir.join("artifacts");

    let init = repro()
        .args(["queue", "init", "--suite", "all", "--scale", "0.05", "--no-csv", "--no-cache"])
        .args(["--workers-hint", "2"])
        .arg("--queue")
        .arg(&queue)
        .arg("--artifacts")
        .arg(&artifacts)
        .output()
        .expect("queue init runs");
    assert!(
        init.status.success(),
        "queue init failed: {}",
        String::from_utf8_lossy(&init.stderr)
    );
    // re-init must refuse
    let reinit = repro()
        .args(["queue", "init", "--suite", "all", "--scale", "0.05", "--no-cache"])
        .arg("--queue")
        .arg(&queue)
        .output()
        .expect("repro runs");
    assert_eq!(reinit.status.code(), Some(1), "re-init must fail");

    // two workers race over the same queue, as separate OS processes
    let workers: Vec<_> = (0..2)
        .map(|i| {
            repro()
                .args(["queue", "work", "--scale", "0.05", "--no-csv", "--no-cache"])
                .args(["--lease-secs", "120"])
                .args(["--worker-id", &format!("racer-{i}")])
                .arg("--queue")
                .arg(&queue)
                .arg("--artifacts")
                .arg(&artifacts)
                .stdout(Stdio::piped())
                .stderr(Stdio::piped())
                .spawn()
                .expect("spawn worker")
        })
        .collect();
    for w in workers {
        let out = w.wait_with_output().expect("worker exits");
        assert!(
            out.status.success(),
            "worker failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(out.stdout.is_empty(), "queue work must keep stdout empty");
    }

    let merged = repro()
        .args(["queue", "merge", "--no-csv", "--no-cache"])
        .arg("--queue")
        .arg(&queue)
        .output()
        .expect("queue merge runs");
    assert!(
        merged.status.success(),
        "merge failed: {}",
        String::from_utf8_lossy(&merged.stderr)
    );

    let single = repro()
        .args(["all", "--jobs", "2", "--scale", "0.05", "--no-csv", "--no-cache"])
        .arg("--artifacts")
        .arg(&artifacts)
        .output()
        .expect("single-process all");
    assert!(single.status.success());
    assert_eq!(
        String::from_utf8_lossy(&merged.stdout),
        String::from_utf8_lossy(&single.stdout),
        "queue merge must be byte-identical to the single-process run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn killing_a_worker_mid_lease_requeues_its_job_and_merge_still_matches() {
    let dir = tmpdir("kill");
    let queue = dir.join("queue");

    let init = repro()
        .args(["queue", "init", "--suite", "sweep", "--scale", "0.05", "--no-csv", "--no-cache"])
        .arg("--queue")
        .arg(&queue)
        .output()
        .expect("queue init runs");
    assert!(init.status.success(), "{}", String::from_utf8_lossy(&init.stderr));

    // worker A claims a job and then plays dead (stall hook, no heartbeat)
    let mut dead = repro()
        .args(["queue", "work", "--scale", "0.05", "--no-csv", "--no-cache"])
        .args(["--lease-secs", "1", "--worker-id", "doomed"])
        .arg("--queue")
        .arg(&queue)
        .env("SHARED_PIM_QUEUE_STALL_MS", "120000")
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn doomed worker");

    // wait until its claim file exists, then kill it mid-lease
    let claimed = queue.join("claimed");
    let deadline = Instant::now() + Duration::from_secs(60);
    let claim_seen = loop {
        let has_claim = std::fs::read_dir(&claimed)
            .map(|rd| {
                rd.flatten()
                    .any(|e| !e.file_name().to_string_lossy().starts_with('.'))
            })
            .unwrap_or(false);
        if has_claim {
            break true;
        }
        if Instant::now() > deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(claim_seen, "doomed worker never claimed a job");
    dead.kill().expect("kill doomed worker");
    let _ = dead.wait();

    // a healthy worker with a 1 s lease requeues the orphaned claim and
    // finishes the whole queue
    let rescue = repro()
        .args(["queue", "work", "--scale", "0.05", "--no-csv", "--no-cache"])
        .args(["--lease-secs", "1", "--worker-id", "rescuer"])
        .arg("--queue")
        .arg(&queue)
        .output()
        .expect("rescue worker runs");
    assert!(
        rescue.status.success(),
        "rescue worker failed: {}",
        String::from_utf8_lossy(&rescue.stderr)
    );

    let merged = repro()
        .args(["queue", "merge", "--no-csv", "--no-cache"])
        .arg("--queue")
        .arg(&queue)
        .output()
        .expect("queue merge runs");
    assert!(
        merged.status.success(),
        "merge after crash failed: {}",
        String::from_utf8_lossy(&merged.stderr)
    );
    let single = repro()
        .args(["sweep", "--jobs", "2", "--scale", "0.05", "--no-csv", "--no-cache"])
        .output()
        .expect("single-process sweep");
    assert!(single.status.success());
    assert_eq!(
        String::from_utf8_lossy(&merged.stdout),
        String::from_utf8_lossy(&single.stdout),
        "post-crash queue merge must still be byte-identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fully_warm_shard_run_reports_all_hits_and_merges_identically_to_cold_all() {
    let dir = tmpdir("warm-shard");
    let cache = dir.join("cache");
    let artifacts = dir.join("artifacts");

    // cold single-process run primes the cache and is the reference report
    let cold = repro()
        .args(["all", "--jobs", "2", "--scale", "0.05", "--no-csv"])
        .arg("--cache")
        .arg(&cache)
        .arg("--artifacts")
        .arg(&artifacts)
        .output()
        .expect("cold all runs");
    assert!(cold.status.success(), "{}", String::from_utf8_lossy(&cold.stderr));
    let cold_err = String::from_utf8_lossy(&cold.stderr);
    assert!(cold_err.contains("hits 0"), "cold run must start empty: {cold_err}");

    // fully warm shard run over the same suite: every job a cache hit
    let manifest_path = dir.join("warm.json");
    let warm = repro()
        .args(["shard", "run", "--suite", "all", "--shard", "0/1"])
        .args(["--scale", "0.05", "--no-csv"])
        .arg("--cache")
        .arg(&cache)
        .arg("--artifacts")
        .arg(&artifacts)
        .arg("--manifest-out")
        .arg(&manifest_path)
        .output()
        .expect("warm shard run");
    assert!(warm.status.success(), "{}", String::from_utf8_lossy(&warm.stderr));

    // the schema-v3 manifest carries the counters: all hits, nothing else
    let manifest = Json::parse(&std::fs::read_to_string(&manifest_path).unwrap())
        .expect("manifest parses");
    let jobs = manifest.get("jobs").and_then(|j| j.as_arr()).expect("jobs").len();
    assert!(jobs > 0);
    let count = |k: &str| manifest.get(&format!("cache.{k}")).and_then(Json::as_u64).unwrap();
    assert_eq!(count("hits"), jobs as u64, "warm run must be 100% hits");
    assert_eq!((count("misses"), count("bypassed")), (0, 0));

    // and the merged warm manifest reproduces the cold report byte-for-byte
    let merged = repro()
        .args(["shard", "merge", "--no-csv"])
        .arg(&manifest_path)
        .output()
        .expect("merge runs");
    assert!(merged.status.success(), "{}", String::from_utf8_lossy(&merged.stderr));
    assert_eq!(
        String::from_utf8_lossy(&merged.stdout),
        String::from_utf8_lossy(&cold.stdout),
        "warm merge must be byte-identical to the cold run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn warm_sweep_banks_rerun_is_zero_miss_and_reproduces_report_and_json() {
    let dir = tmpdir("warm-banks");
    let cache = dir.join("cache");
    let run = |bench: &PathBuf| {
        let out = repro()
            .args(["sweep-banks", "--jobs", "2", "--scale", "0.05", "--no-csv"])
            .arg("--cache")
            .arg(&cache)
            .arg("--bench-out")
            .arg(bench)
            .output()
            .expect("sweep-banks runs");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        out
    };
    let b1 = dir.join("b1.json");
    let b2 = dir.join("b2.json");
    let first = run(&b1);
    let second = run(&b2);
    let err = String::from_utf8_lossy(&second.stderr);
    assert!(
        err.contains("misses 0, bypassed 0"),
        "second run must be fully warm: {err}"
    );
    assert_eq!(
        String::from_utf8_lossy(&first.stdout),
        String::from_utf8_lossy(&second.stdout),
        "warm report diverged"
    );
    assert_eq!(
        std::fs::read(&b1).unwrap(),
        std::fs::read(&b2).unwrap(),
        "warm bench JSON diverged"
    );

    // `repro cache stats` sees the entries; `gc` keeps them (same model)
    let stats = repro()
        .args(["cache", "stats"])
        .arg("--cache")
        .arg(&cache)
        .output()
        .expect("cache stats runs");
    assert!(stats.status.success());
    let text = String::from_utf8_lossy(&stats.stdout);
    assert!(text.contains("suite sweep-banks"), "stats: {text}");
    assert!(!text.contains(" 0 entries"), "stats must count entries: {text}");
    let gc = repro()
        .args(["cache", "gc"])
        .arg("--cache")
        .arg(&cache)
        .output()
        .expect("cache gc runs");
    assert!(gc.status.success());
    assert!(
        String::from_utf8_lossy(&gc.stdout).contains("removed 0 entries"),
        "same-model entries must survive gc"
    );
    std::fs::remove_dir_all(&dir).ok();
}
