//! End-to-end coverage of the `repro serve` daemon and the `repro loadtest`
//! harness, driven through real subprocesses:
//!
//! - a warm repeated request is answered entirely from the job cache (zero
//!   misses) with a body byte-identical to both the cold response and the
//!   `repro sweep` CLI stdout for the same request;
//! - duplicate concurrent cold requests coalesce into a single execution
//!   (counted by `/stats`) and fan out identical bodies;
//! - past `--max-inflight`, cold requests bounce with `429` + `Retry-After`
//!   and succeed on retry;
//! - `POST /shutdown` drains in-flight work: the parked request still gets
//!   its `200` and the daemon exits cleanly;
//! - `repro loadtest` writes a `BENCH_serve.json` that `repro gate` accepts
//!   against the checked-in repo baseline (the CI serve-smoke job).
//!
//! The daemons bind `127.0.0.1:0` and announce the chosen port on stdout,
//! so concurrent tests never collide.

use shared_pim::coordinator::{http_get, http_post, SimRequest, Suite};
use shared_pim::util::json::Json;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spim-serve-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A running `repro serve` subprocess plus the address it bound.
struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    /// Spawn a daemon on a free port with its own artifact/cache dirs under
    /// `dir`, wait for the announce line, and return the bound address.
    fn start(dir: &Path, extra: &[&str], stall_ms: Option<u64>) -> Daemon {
        let mut cmd = repro();
        cmd.args(["serve", "--addr", "127.0.0.1:0", "--scale", "0.05"])
            .arg("--artifacts")
            .arg(dir.join("artifacts"))
            .arg("--cache")
            .arg(dir.join("cache"))
            .args(extra)
            .stdout(Stdio::piped())
            .stderr(Stdio::null());
        match stall_ms {
            Some(ms) => cmd.env("SHARED_PIM_SERVE_STALL_MS", ms.to_string()),
            None => cmd.env_remove("SHARED_PIM_SERVE_STALL_MS"),
        };
        let mut child = cmd.spawn().expect("spawn repro serve");
        let stdout = child.stdout.take().expect("stdout is piped");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("read announce line");
        let addr = line
            .trim()
            .strip_prefix("serve: listening on http://")
            .unwrap_or_else(|| panic!("unexpected announce line: {line:?}"))
            .to_string();
        Daemon { child, addr }
    }

    /// Graceful stop: `POST /shutdown`, then require a clean exit.
    fn shutdown(mut self) {
        let resp = http_post(&self.addr, "/shutdown", "").expect("shutdown reaches the daemon");
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, "shutting down\n");
        let status = self.child.wait().expect("daemon exits");
        assert!(status.success(), "daemon exited with {status:?}");
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        // a failed assertion must not leak a daemon past the test run
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn sweep_body(scale: f64) -> String {
    format!("{}\n", SimRequest::new(Suite::Sweep, scale).to_json().to_string_pretty())
}

#[test]
fn warm_repeat_is_all_hits_and_byte_identical_to_the_cli() {
    let dir = tmpdir("warm");
    let daemon = Daemon::start(&dir, &[], None);

    let health = http_get(&daemon.addr, "/health").expect("health");
    assert_eq!((health.status, health.body.as_str()), (200, "ok\n"));

    let body = sweep_body(0.05);
    let cold = http_post(&daemon.addr, "/run", &body).expect("cold request");
    assert_eq!(cold.status, 200, "cold run failed: {}", cold.body);
    assert!(
        cold.header_u64("x-repro-cache-misses").unwrap_or(0) > 0,
        "first request of a fresh daemon must miss"
    );

    let warm = http_post(&daemon.addr, "/run", &body).expect("warm request");
    assert_eq!(warm.status, 200);
    assert_eq!(
        warm.header_u64("x-repro-cache-misses"),
        Some(0),
        "repeated request must be answered entirely from the cache"
    );
    assert!(warm.header_u64("x-repro-cache-hits").unwrap_or(0) > 0);
    assert_eq!(warm.body, cold.body, "warm and cold bodies must be byte-identical");
    assert_eq!(
        warm.header("x-repro-digest"),
        Some(SimRequest::new(Suite::Sweep, 0.05).digest().as_str())
    );

    // the daemon's body is exactly what the batch CLI prints for the same
    // request (cold, cache off — the byte-identity contract)
    let cli = repro()
        .args(["sweep", "--scale", "0.05", "--no-csv", "--no-cache"])
        .arg("--artifacts")
        .arg(dir.join("cli-artifacts"))
        .output()
        .expect("repro sweep runs");
    assert!(cli.status.success(), "{}", String::from_utf8_lossy(&cli.stderr));
    assert_eq!(
        String::from_utf8_lossy(&cli.stdout),
        warm.body,
        "daemon response and `repro sweep` stdout must be byte-identical"
    );

    let stats = http_get(&daemon.addr, "/stats").expect("stats");
    let j = Json::parse(&stats.body).expect("stats is JSON");
    assert_eq!(j.get("executions").and_then(Json::as_u64), Some(2));
    assert_eq!(j.get("rejected").and_then(Json::as_u64), Some(0));

    daemon.shutdown();
}

#[test]
fn duplicate_concurrent_requests_coalesce_into_one_execution() {
    let dir = tmpdir("coalesce");
    // the stall widens the in-flight window so both clients overlap
    let daemon = Daemon::start(&dir, &["--max-inflight", "4"], Some(1200));
    let body = sweep_body(0.0511);

    let (a, b) = std::thread::scope(|s| {
        let ta = s.spawn(|| http_post(&daemon.addr, "/run", &body).expect("request a"));
        let tb = s.spawn(|| http_post(&daemon.addr, "/run", &body).expect("request b"));
        (ta.join().unwrap(), tb.join().unwrap())
    });
    assert_eq!((a.status, b.status), (200, 200));
    assert_eq!(a.body, b.body, "coalesced responses must be byte-identical");
    let coalesced_marks =
        [&a, &b].iter().filter(|r| r.header("x-repro-coalesced").is_some()).count();
    assert_eq!(coalesced_marks, 1, "exactly one response rode the other's execution");

    let stats = http_get(&daemon.addr, "/stats").expect("stats");
    let j = Json::parse(&stats.body).expect("stats is JSON");
    assert_eq!(
        j.get("executions").and_then(Json::as_u64),
        Some(1),
        "identical concurrent requests must execute exactly once"
    );
    assert_eq!(j.get("coalesced").and_then(Json::as_u64), Some(1));

    daemon.shutdown();
}

#[test]
fn admission_control_rejects_past_max_inflight_and_recovers() {
    let dir = tmpdir("admission");
    let daemon = Daemon::start(&dir, &["--max-inflight", "1"], Some(1200));

    let slow_body = sweep_body(0.0521);
    let other_body = sweep_body(0.0522);
    std::thread::scope(|s| {
        let slow = s.spawn(|| http_post(&daemon.addr, "/run", &slow_body).expect("slow request"));
        // give the slow request time to claim the single in-flight slot
        std::thread::sleep(Duration::from_millis(300));
        let bounced = http_post(&daemon.addr, "/run", &other_body).expect("bounced request");
        assert_eq!(bounced.status, 429, "over capacity must bounce: {}", bounced.body);
        assert_eq!(bounced.header("retry-after"), Some("1"));
        let slow = slow.join().unwrap();
        assert_eq!(slow.status, 200, "the admitted request still completes");
    });

    // capacity freed: the bounced request succeeds on retry
    let retried = http_post(&daemon.addr, "/run", &other_body).expect("retry");
    assert_eq!(retried.status, 200);

    let stats = http_get(&daemon.addr, "/stats").expect("stats");
    let j = Json::parse(&stats.body).expect("stats is JSON");
    assert_eq!(j.get("rejected").and_then(Json::as_u64), Some(1));

    daemon.shutdown();
}

#[test]
fn shutdown_drains_inflight_work() {
    let dir = tmpdir("drain");
    let daemon = Daemon::start(&dir, &[], Some(1200));
    let body = sweep_body(0.0531);

    std::thread::scope(|s| {
        let parked = s.spawn(|| http_post(&daemon.addr, "/run", &body).expect("in-flight request"));
        std::thread::sleep(Duration::from_millis(300));
        let resp = http_post(&daemon.addr, "/shutdown", "").expect("shutdown");
        assert_eq!(resp.status, 200);
        let parked = parked.join().unwrap();
        assert_eq!(parked.status, 200, "in-flight work must be drained, not dropped");
        assert!(!parked.body.is_empty());
    });
    let mut daemon = daemon;
    let status = daemon.child.wait().expect("daemon exits after drain");
    assert!(status.success(), "daemon exited with {status:?}");
}

#[test]
fn loadtest_writes_a_bench_the_gate_accepts() {
    let dir = tmpdir("loadtest");
    let daemon = Daemon::start(&dir, &["--max-inflight", "4"], None);
    let bench = dir.join("BENCH_serve.json");

    let lt = repro()
        .args(["loadtest", "--requests", "12", "--warm-frac", "0.5"])
        .args(["--concurrency", "4", "--scale", "0.05", "--max-p99-ms", "120000"])
        .args(["--addr", &daemon.addr])
        .arg("--bench-out")
        .arg(&bench)
        .output()
        .expect("repro loadtest runs");
    assert!(
        lt.status.success(),
        "loadtest failed:\n{}\n{}",
        String::from_utf8_lossy(&lt.stdout),
        String::from_utf8_lossy(&lt.stderr)
    );
    let stdout = String::from_utf8_lossy(&lt.stdout);
    assert!(stdout.contains("loadtest: 12/12 ok"), "got: {stdout}");

    let report = Json::parse(&std::fs::read_to_string(&bench).expect("bench written"))
        .expect("bench is JSON");
    assert_eq!(
        report.get("schema").and_then(Json::as_str),
        Some("shared-pim/serve-bench/v1")
    );
    assert_eq!(report.get("completed").and_then(Json::as_u64), Some(12));

    // warm half of the stream: the measured hit rate must be visible
    let metrics = report.get("metrics").and_then(Json::as_arr).expect("metrics");
    let hit_rate = metrics
        .iter()
        .find(|m| m.get("name").and_then(Json::as_str) == Some("cache_hit_rate_pct"))
        .and_then(|m| m.get("value").and_then(Json::as_f64))
        .expect("hit-rate metric present");
    assert!(hit_rate > 0.0, "a 50% warm stream must produce cache hits, got {hit_rate}");

    // the fresh report gates cleanly against the checked-in repo baseline
    // (generous bounds), and against itself at zero tolerance
    let baseline = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    for (base, tol) in [(baseline, "10"), (bench.to_str().unwrap(), "0")] {
        let gate = repro()
            .args(["gate", "--baseline", base, "--tol-pct", tol])
            .arg("--current")
            .arg(&bench)
            .output()
            .expect("repro gate runs");
        assert!(
            gate.status.success(),
            "gate vs {base} failed:\n{}\n{}",
            String::from_utf8_lossy(&gate.stdout),
            String::from_utf8_lossy(&gate.stderr)
        );
    }

    daemon.shutdown();
}
