//! End-to-end calibration from a bare build: `repro calibrate` must succeed
//! with no artifacts/ anywhere (native backend auto-selected), write
//! `calibration.json`, round-trip through `Calibration::save`/`load`, and
//! produce circuit-sane, JEDEC-clean numbers. Also covers the strict
//! `--backend pjrt` failure path and the stale-manifest fallback (a
//! manifest failing `spec::check_manifest` degrades to native with a
//! warning instead of aborting).

use shared_pim::calibrate::{run_calibration, Calibration};
use shared_pim::config::DramConfig;
use shared_pim::transient::NativeBackend;
use std::path::PathBuf;
use std::process::Command;

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spim-cal-e2e-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Circuit sanity of the native calibration, in-process. Mirrors the PJRT
/// round-trip assertions (tests/runtime_roundtrip.rs) so both backends are
/// held to the same physics — but this one runs everywhere.
#[test]
fn native_calibration_is_jedec_clean_and_circuit_sane() {
    let cal = run_calibration(&NativeBackend, &DramConfig::table1_ddr3())
        .expect("native calibration");
    assert!(cal.jedec_ok, "circuit must fit JEDEC windows: {cal:?}");
    // paper: broadcast to 4 destinations within DDR timing
    assert!(cal.max_broadcast >= 4, "max broadcast {}", cal.max_broadcast);
    // sense within tRCD-class windows
    assert!(cal.t_sense_local_ns > 0.0 && cal.t_sense_local_ns < 14.0, "{cal:?}");
    assert!(cal.t_bus_sense_ns > 0.0 && cal.t_bus_sense_ns < 14.0, "{cal:?}");
    assert!(cal.t_gwl_share_ns >= 0.5 && cal.t_gwl_share_ns < 8.0, "{cal:?}");
    // sane ordering: the staged shared-row bus phase (charge share + BK-SA
    // sense) is *faster* than a fresh local activate — the circuit fact
    // behind the paper's concurrent compute+transfer claim
    assert!(
        cal.t_gwl_share_ns + cal.t_bus_sense_ns < cal.t_sense_local_ns,
        "bus path must outpace a local activate: {cal:?}"
    );
    // broadcast settle grows (weakly) with fan-out
    let s = &cal.broadcast_settle_ns;
    assert_eq!(s.len(), 6);
    assert!(s[0] <= s[3] + 1e-9, "settle must grow with fan-out: {s:?}");
    assert!(cal.copy_energy_fj_per_col > 0.0, "{cal:?}");
}

#[test]
fn repro_calibrate_runs_from_bare_build_and_round_trips() {
    let dir = tmpdir("bare");
    let artifacts = dir.join("artifacts"); // deliberately never created here
    let run = || {
        repro()
            .args(["calibrate", "--artifacts"])
            .arg(&artifacts)
            .output()
            .expect("repro calibrate runs")
    };
    let out = run();
    assert!(
        out.status.success(),
        "bare-build calibrate must succeed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("transient backend: native"), "stdout: {stdout}");
    assert!(stdout.contains("jedec_ok true"), "stdout: {stdout}");

    // round-trip the artifact it wrote
    let path = artifacts.join("calibration.json");
    assert!(path.exists(), "calibrate must write calibration.json");
    let cal = Calibration::load(&artifacts).expect("load calibration.json");
    assert!(cal.jedec_ok);
    assert!(cal.max_broadcast >= 1);
    assert!(cal.t_gwl_share_ns + cal.t_bus_sense_ns < cal.t_sense_local_ns, "{cal:?}");

    // determinism: a second run rewrites byte-identical JSON
    let first = std::fs::read(&path).unwrap();
    assert!(run().status.success());
    assert_eq!(first, std::fs::read(&path).unwrap(), "calibration.json must be bit-stable");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn explicit_backend_choices_are_strict() {
    let dir = tmpdir("strict");
    // --backend pjrt without artifacts: hard error, no silent fallback
    let out = repro()
        .args(["calibrate", "--backend", "pjrt", "--artifacts"])
        .arg(dir.join("artifacts"))
        .output()
        .expect("repro runs");
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("no usable transient backend"), "stderr: {err}");

    // unknown backend value: usage error
    let out = repro()
        .args(["calibrate", "--backend", "warp-drive"])
        .output()
        .expect("repro runs");
    assert_eq!(out.status.code(), Some(2));

    // --backend native works even when pointed at a nonexistent dir
    let out = repro()
        .args(["calibrate", "--backend", "native", "--artifacts"])
        .arg(dir.join("artifacts-native"))
        .output()
        .expect("repro runs");
    assert!(out.status.success());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_manifest_falls_back_to_native_with_warning_not_abort() {
    let dir = tmpdir("stale");
    let bad = dir.join("artifacts");
    std::fs::create_dir_all(&bad).unwrap();
    // parses fine, fails spec::check_manifest (n_cols mismatch); the
    // fixture builder lives next to check_manifest so it tracks the spec
    let stale = shared_pim::calibrate::spec::stale_manifest_json_for_tests();
    std::fs::write(bad.join("manifest.json"), stale).unwrap();
    std::fs::write(bad.join("transient.hlo.txt"), "HloModule bogus").unwrap();

    let out = repro()
        .args(["calibrate", "--artifacts"])
        .arg(&bad)
        .output()
        .expect("repro runs");
    assert!(
        out.status.success(),
        "stale artifacts must not abort calibrate: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("falling back to the native transient backend"), "stderr: {err}");
    assert!(String::from_utf8_lossy(&out.stdout).contains("transient backend: native"));

    // fig5 under the stale dir: same fallback, and its report is
    // byte-identical to a clean bare-artifacts run
    let fig5 = |artifacts: &PathBuf| {
        repro()
            .args(["exp", "fig5", "--no-csv", "--artifacts"])
            .arg(artifacts)
            .output()
            .expect("repro exp fig5 runs")
    };
    let stale = fig5(&bad);
    assert!(
        stale.status.success(),
        "fig5 must survive stale artifacts: {}",
        String::from_utf8_lossy(&stale.stderr)
    );
    let clean_dir = dir.join("clean-artifacts");
    let clean = fig5(&clean_dir);
    assert!(clean.status.success());
    assert_eq!(
        String::from_utf8_lossy(&stale.stdout),
        String::from_utf8_lossy(&clean.stdout),
        "fallback fig5 must match the bare-build report byte-for-byte"
    );
    assert!(String::from_utf8_lossy(&clean.stdout).contains("Fig. 5"));
    std::fs::remove_dir_all(&dir).ok();
}
