//! Property tests for the calibration primitives: `settle_time_ns` (the
//! waveform-threshold extractor every circuit timing is derived from) and
//! `spec::check_manifest` (the stale-artifact gate the backend selector
//! relies on).

use shared_pim::calibrate::{settle_time_ns, spec};
use shared_pim::prop_assert;
use shared_pim::runtime::Manifest;
use shared_pim::util::propcheck::propcheck;

#[test]
fn monotone_ramps_settle_at_the_analytic_crossing() {
    propcheck(300, |g| {
        let n = g.usize_in(2, 200);
        let start = g.f64_in(0.0, 0.5);
        let end = start + g.f64_in(0.1, 1.0);
        let level = start + g.f64_in(0.02, 0.98) * (end - start);
        let dt = g.f64_in(0.1, 1.0);
        let slope = (end - start) / (n - 1) as f64;
        let trace: Vec<f32> = (0..n).map(|i| (start + slope * i as f64) as f32).collect();

        let t = settle_time_ns(&trace, level as f32, dt);
        let t = match t {
            Some(t) => t,
            None => return Err(format!("monotone ramp through {level} never settled")),
        };
        let k = (t / dt).round() as usize;
        prop_assert!((k as f64 * dt - t).abs() < 1e-9, "t {} is not a step multiple", t);
        // defining property of the crossing on a monotone trace: first
        // index at-or-above the level...
        prop_assert!(trace[k] >= level as f32, "trace[{}]={} below level {}", k, trace[k], level);
        prop_assert!(
            k == 0 || trace[k - 1] < level as f32,
            "crossing not minimal: trace[{}]={} already >= {}",
            k - 1,
            trace[k.max(1) - 1],
            level
        );
        // ...and it sits within one step of the analytic f64 crossing
        // (f32 quantization of the trace can shift it by at most one)
        let analytic = ((level - start) / slope).ceil() as usize;
        prop_assert!(
            k.abs_diff(analytic) <= 1,
            "crossing {} vs analytic {} (start {}, slope {}, level {})",
            k,
            analytic,
            start,
            slope,
            level
        );
        Ok(())
    });
}

#[test]
fn dips_after_a_crossing_report_the_last_sustained_crossing() {
    propcheck(300, |g| {
        let level = g.f64_in(0.5, 1.0) as f32;
        let below = |g: &mut shared_pim::util::propcheck::Gen| level - g.f64_in(0.01, 0.5) as f32;
        let above = |g: &mut shared_pim::util::propcheck::Gen| level + g.f64_in(0.01, 0.5) as f32;
        let lead = g.usize_in(0, 20);
        let rise = g.usize_in(1, 20);
        let dip = g.usize_in(1, 10);
        let tail = g.usize_in(1, 30);
        let mut trace = Vec::new();
        for _ in 0..lead {
            trace.push(below(g));
        }
        for _ in 0..rise {
            trace.push(above(g)); // an earlier crossing...
        }
        for _ in 0..dip {
            trace.push(below(g)); // ...that does not hold
        }
        for _ in 0..tail {
            trace.push(above(g)); // the sustained one
        }
        let dt = g.f64_in(0.1, 1.0);
        let expect = (lead + rise + dip) as f64 * dt;
        let got = settle_time_ns(&trace, level, dt);
        prop_assert!(
            got == Some(expect),
            "expected settle at {} (start of the sustained tail), got {:?}",
            expect,
            got
        );
        Ok(())
    });
}

#[test]
fn never_settling_traces_return_none() {
    propcheck(300, |g| {
        let level = g.f64_in(0.5, 1.0) as f32;
        let n = g.usize_in(0, 100);
        // strictly below the level throughout
        let mut trace: Vec<f32> =
            (0..n).map(|_| level - g.f64_in(0.001, 0.5) as f32).collect();
        prop_assert!(
            settle_time_ns(&trace, level, 0.4).is_none(),
            "all-below trace settled: {:?}",
            trace
        );
        // a crossing that fails to hold through the end is not settled either
        let rise = g.usize_in(1, 10);
        for _ in 0..rise {
            trace.push(level + g.f64_in(0.01, 0.5) as f32);
        }
        trace.push(level - g.f64_in(0.01, 0.5) as f32); // ends in a dip
        prop_assert!(
            settle_time_ns(&trace, level, 0.4).is_none(),
            "end-dipping trace settled: {:?}",
            trace
        );
        Ok(())
    });
}

fn good_manifest() -> Manifest {
    Manifest {
        version: 1,
        n_cols: spec::N_COLS,
        n_state: spec::N_STATE,
        n_flags: spec::N_FLAGS,
        n_params: spec::N_PARAMS,
        n_steps: spec::N_STEPS,
        inner: spec::INNER,
        n_outer: spec::N_OUTER,
        defaults: vec![0.0; spec::N_PARAMS],
    }
}

#[test]
fn check_manifest_accepts_the_compiled_in_spec() {
    spec::check_manifest(&good_manifest()).expect("matching manifest must pass");
}

#[test]
fn check_manifest_rejects_every_stale_field_variant() {
    propcheck(300, |g| {
        let field = g.usize_in(0, 7);
        let delta = 1 + g.u64_below(10_000) as usize;
        let bump = |v: usize, up: bool| if up { v + delta } else { v.saturating_sub(delta) };
        let up = g.bool();
        let mut m = good_manifest();
        let name = match field {
            0 => {
                m.version = if up { m.version + delta as u64 } else { 0 };
                "version"
            }
            1 => {
                m.n_cols = bump(m.n_cols, up);
                "n_cols"
            }
            2 => {
                m.n_state = bump(m.n_state, up);
                "n_state"
            }
            3 => {
                m.n_flags = bump(m.n_flags, up);
                "n_flags"
            }
            4 => {
                m.n_params = bump(m.n_params, up);
                "n_params"
            }
            5 => {
                m.n_steps = bump(m.n_steps, up);
                "n_steps"
            }
            6 => {
                m.inner = bump(m.inner, up);
                "inner"
            }
            _ => {
                m.n_outer = bump(m.n_outer, up);
                "n_outer"
            }
        };
        // saturating_sub can only collide with the original when it is a
        // no-op; every spec constant is > 0, so a nonzero delta always
        // lands on a different value — unless it saturates to the same 0,
        // which cannot happen here. Guard anyway for version=0's `up` arm.
        let unchanged = match field {
            0 => m.version == 1,
            1 => m.n_cols == spec::N_COLS,
            2 => m.n_state == spec::N_STATE,
            3 => m.n_flags == spec::N_FLAGS,
            4 => m.n_params == spec::N_PARAMS,
            5 => m.n_steps == spec::N_STEPS,
            6 => m.inner == spec::INNER,
            _ => m.n_outer == spec::N_OUTER,
        };
        if unchanged {
            return Ok(()); // degenerate draw: nothing was actually perturbed
        }
        let err = match spec::check_manifest(&m) {
            Err(e) => e.to_string(),
            Ok(()) => return Err(format!("stale {name} (delta {delta}) accepted")),
        };
        prop_assert!(
            err.contains(name) || name == "version" && err.contains("manifest"),
            "error for stale {} must name the field, got: {}",
            name,
            err
        );
        Ok(())
    });
}
