//! Property coverage of the typed request API (`coordinator::SimRequest`):
//!
//! - any valid request round-trips through its JSON wire format with the
//!   same fields, digest, and compiled job list;
//! - a request built from CLI words equals the request built from the
//!   equivalent JSON body (the CLI and the serve endpoint provably ask for
//!   the same run).

use shared_pim::coordinator::{CachePolicy, SimRequest, Suite, Topology};
use shared_pim::prop_assert;
use shared_pim::runtime::BackendChoice;
use shared_pim::util::cli::Args;
use shared_pim::util::json::Json;
use shared_pim::util::propcheck::{propcheck, Gen};
use std::path::PathBuf;

/// Draw one valid request: any suite, a positive scale, any backend, a
/// random (valid) topology ladder on suites that carry bank-scaling jobs,
/// and any cache policy.
fn gen_request(g: &mut Gen) -> SimRequest {
    let suite = *g.choose(&[Suite::All, Suite::Sweep, Suite::SweepBanks]);
    let scale = g.f64_in(0.01, 2.0);
    let backend = *g.choose(&[BackendChoice::Auto, BackendChoice::Native, BackendChoice::Pjrt]);
    let topology = if suite != Suite::Sweep && g.bool() {
        // a nonempty, strictly ascending subset of the power-of-two ladder
        let all = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
        let mut counts: Vec<usize> = all.iter().copied().filter(|_| g.bool()).collect();
        if counts.is_empty() {
            counts.push(all[g.usize_in(0, all.len() - 1)]);
        }
        Topology::Banks(counts)
    } else {
        Topology::Default
    };
    let cache = match g.usize_in(0, 2) {
        0 => CachePolicy::Inherit,
        1 => CachePolicy::Disabled,
        _ => CachePolicy::Dir(PathBuf::from(format!("cache-{}", g.usize_in(0, 9)))),
    };
    SimRequest { suite, scale, backend, topology, cache }
}

#[test]
fn any_valid_request_round_trips_through_json() {
    propcheck(150, |g| {
        let req = gen_request(g);
        prop_assert!(req.validate().is_ok(), "generator made an invalid request: {req:?}");
        let text = format!("{}\n", req.to_json().to_string_pretty());
        let back = match Json::parse(&text).map_err(|e| e.to_string()).and_then(|j| {
            SimRequest::from_json(&j).map_err(|e| e.to_string())
        }) {
            Ok(b) => b,
            Err(e) => return Err(format!("round trip failed for {req:?}: {e}")),
        };
        prop_assert!(back == req, "round trip changed the request: {req:?} -> {back:?}");
        prop_assert!(back.digest() == req.digest(), "round trip changed the digest");
        prop_assert!(back.into_jobs() == req.into_jobs(), "round trip changed the job list");
        Ok(())
    });
}

#[test]
fn cli_words_and_json_bodies_compile_to_the_same_request() {
    propcheck(100, |g| {
        let req = gen_request(g);
        // render the request back into the CLI words `repro <suite>` takes...
        let mut argv: Vec<String> = vec![
            req.suite.name().to_string(),
            "--scale".to_string(),
            req.scale.to_string(),
            "--backend".to_string(),
            req.backend.name().to_string(),
        ];
        if let Topology::Banks(counts) = &req.topology {
            let spec: Vec<String> = counts.iter().map(|b| b.to_string()).collect();
            argv.push("--banks".to_string());
            argv.push(spec.join(","));
        }
        match &req.cache {
            CachePolicy::Inherit => {}
            CachePolicy::Disabled => argv.push("--no-cache".to_string()),
            CachePolicy::Dir(d) => {
                argv.push("--cache".to_string());
                argv.push(d.display().to_string());
            }
        }
        let args = Args::parse_with_flags(argv.into_iter(), &["no-csv", "no-cache"]);
        let from_cli = match SimRequest::from_args(&args, req.suite) {
            Ok(r) => r,
            Err(e) => return Err(format!("CLI adapter rejected {req:?}: {e:#}")),
        };
        // ...and into the JSON body the serve endpoint takes
        let from_json = match SimRequest::from_json(&req.to_json()) {
            Ok(r) => r,
            Err(e) => return Err(format!("JSON adapter rejected {req:?}: {e:#}")),
        };
        prop_assert!(from_cli == req, "CLI path changed the request: {req:?} -> {from_cli:?}");
        prop_assert!(from_json == req, "JSON path changed the request");
        prop_assert!(
            from_cli.digest() == from_json.digest(),
            "CLI-built and JSON-built digests disagree for {req:?}"
        );
        prop_assert!(
            from_cli.into_jobs() == from_json.into_jobs(),
            "CLI-built and JSON-built job lists disagree for {req:?}"
        );
        Ok(())
    });
}
